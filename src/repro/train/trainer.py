"""Training step: microbatched grad accumulation + communicator-mediated
sync.

Three gradient-synchronisation modes (the paper's A/B/C):

  auto       — GSPMD end-to-end: batch sharded over ("pod","data"), XLA
               inserts every collective (the conventional generic stack).
  composed   — the loss/grad computation runs inside ``substrate.shard_map``
               manual over the data axes (model axes stay auto); gradients
               are synced through a ``repro.comm`` communicator whose
               per-function protocols are cost-model-selected
               (ring / two-phase / hierarchical).
  compressed — composed + int8 error-feedback compressed all-reduce
               (feature injected in the protocol, paper §4); the EF
               residual lives in the train state and persists across steps.

Distributed work routes through the Sessions-style facade: pass
``comm=`` (a ``repro.comm.Communicator``, usually ``session.world``) to
``make_train_step``; the step splits it into the data-axis
sub-communicator internally.  ``mesh=``+``engine=`` is the pre-PR-4
spelling, adopted into a session-less communicator for back-compat.

Gradient bucketing (``TrainCfg.bucket_grads``) is a beyond-paper
optimization: leaves are grouped by dtype (bf16 stays bf16 on the wire)
and fused into buckets of at most ``TrainCfg.bucket_bytes``, each an
independent cost-model-planned collective (``comm.
sync_gradients_bucketed``) so the alpha term amortizes and XLA overlaps
the buckets.

``TrainCfg.overlap`` (``--overlap`` on the launch CLI) switches the sync
to the nonblocking start/wait protocol (MPI Advance's MPIX_Start/Wait
analogue): the last microbatch is peeled out of the accumulation scan,
buckets (or leaves) are synced in reverse layout order through persistent
handles / two-phase communicator arms, and each unit's start phase is in
flight while its neighbour reduces and the peeled backward runs.  The
overlapped path performs the exact same arithmetic as the blocking one —
losses are bit-identical (tests/test_overlap.py).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import comm as comm_mod
from repro.core import plan as plan_mod
from repro.core import schedule as schedule_mod
from repro.core.compression import EFState, bucket_ef_zeros
from repro.parallel.sharding import shard_hint
from repro.runtime import substrate

Params = Any


@dataclasses.dataclass(frozen=True)
class TrainCfg:
    microbatches: int = 1
    sync_mode: str = "auto"              # auto | composed | compressed
    data_axes: Tuple[str, ...] = ("pod", "data")
    bucket_grads: bool = False           # beyond-paper: fused dtype buckets
    bucket_bytes: int = plan_mod.DEFAULT_BUCKET_BYTES  # size cap per bucket
    grad_dtype: Any = jnp.float32        # accumulation dtype
    overlap: bool = False                # nonblocking start/wait grad sync
    # peel the last microbatch out of the accumulation scan so bucket
    # starts overlap its backward.  None = auto: peel on accelerator
    # backends, skip on CPU hosts (no async dispatch to overlap with —
    # inlining a second copy of the model body only slows the step).
    overlap_peel: Any = None             # True | False | None (auto)
    # in-flight collectives the schedule IR's interleave pass keeps live.
    # 2 = the classic depth-2 software pipeline (no progress hops, the
    # bit-identity reference); >=3 adds per-stage progress() hops that
    # drain wait-phase stages of younger in-flight units early.
    overlap_depth: int = 2
    # ZeRO-1: gradients sync with only the reduce-scatter half of the
    # planned all-reduce, each data-parallel rank updates its shard of a
    # data-axis-sharded optimizer state (1/N memory), and updated params
    # all-gather back through the schedule IR.  Elementwise updates make
    # losses bit-identical to the unsharded composed path at clip_norm=0
    # on pow2 data-parallel sizes; elsewhere odd per-rank chunks drop the
    # bidir-ring RS to plain ring, whose summation order differs from the
    # all-reduce's in the last ulp.
    zero: bool = False

    def __post_init__(self):
        if not self.zero:
            return
        if self.sync_mode != "composed":
            raise ValueError(
                f"zero=True shards the optimizer update on the planned "
                f"all-reduce's RS/AG seam, which only the composed sync "
                f"path exposes (compression's EF residual would defeat "
                f"the sharding); got sync_mode={self.sync_mode!r}")
        if self.bucket_grads:
            raise ValueError(
                "zero=True runs one RS/AG pair per parameter leaf — "
                "fused buckets cross leaf boundaries and have no "
                "per-param shard to update; disable bucket_grads")


def _tree_size(tree) -> int:
    return sum(l.size for l in jax.tree_util.tree_leaves(tree))


def _grad_structs(params, cfg: TrainCfg):
    """Abstract leaves with the dtype gradients actually have in the step:
    microbatched accumulation casts to ``grad_dtype``; a single microbatch
    keeps each param's own dtype."""
    return [jax.ShapeDtypeStruct(
                l.shape, cfg.grad_dtype if cfg.microbatches > 1 else l.dtype)
            for l in jax.tree_util.tree_leaves(params)]


def grad_bucket_plan(params, cfg: TrainCfg) -> tuple:
    """The dtype-grouped bucket layout the step's fused sync will use —
    deterministic in (shapes, dtypes, order, bucket_bytes), so state
    creation and the traced step always agree."""
    return plan_mod.plan_buckets(_grad_structs(params, cfg), cfg.bucket_bytes)


# ---------------------------------------------------------------------------
# ZeRO-1 state layout (data-parallel-degree dependent, hence mesh=)
# ---------------------------------------------------------------------------

def zero_layout(cfg: TrainCfg, mesh) -> Tuple[str, int]:
    """(axis, size) of the single data axis ZeRO-1 shards over."""
    if mesh is None:
        raise ValueError("zero=True makes the optimizer-state layout "
                         "data-parallel-degree dependent; pass mesh=")
    sizes = dict(mesh.shape)
    axes = tuple(a for a in cfg.data_axes if a in sizes)
    if len(axes) != 1:
        raise ValueError(
            f"zero=True shards optimizer state over exactly ONE data "
            f"axis; cfg.data_axes={cfg.data_axes} resolves to {axes} on "
            f"mesh axes {tuple(sizes)}")
    return axes[0], int(sizes[axes[0]])


def _zero_pad_len(n: int, p: int) -> int:
    return ((int(n) + p - 1) // p) * p


def _zero_flat_params(params, p: int, abstract: bool):
    """The global ZeRO optimizer-state layout: each param leaf flattened
    and zero-padded to a multiple of the data-parallel size — i.e. the
    concatenation of the per-rank padded-flat chunks the RS protocols
    produce, with all padding as TRAILING zeros (which is what makes
    restore-time re-sharding onto a different survivor mesh a pure
    truncate/re-pad)."""
    def leaf(l):
        n = _zero_pad_len(l.size, p)
        if abstract:
            return jax.ShapeDtypeStruct((n,), l.dtype)
        return jnp.zeros((n,), l.dtype)
    return jax.tree_util.tree_map(leaf, params)


def _zero_chunk(x, p: int, idx):
    """This rank's padded-flat chunk of ``x`` — the exact pad-and-split
    layout the RS protocols use, so param chunks line up element-for-
    element with the reduced grad chunks."""
    flat = x.reshape(-1)
    rem = (-flat.shape[0]) % p
    if rem:
        flat = jnp.concatenate([flat, jnp.zeros((rem,), flat.dtype)])
    c = flat.shape[0] // p
    return jax.lax.dynamic_slice_in_dim(flat, idx * c, c)


def _zero_opt_specs(model, optimizer, cfg: TrainCfg, mesh):
    """Optimizer-state specs for the ZeRO layout: every flat leaf sharded
    over the data axis on dim 0 (the optimizer's own state_specs machinery
    runs over the flat layout, so AdamW and Adafactor both land here —
    1-D leaves take Adafactor's unfactored branch)."""
    ax, zp = zero_layout(cfg, mesh)
    params = model.abstract_params()
    pspecs = jax.tree_util.tree_map(lambda _: P(ax), params)
    return optimizer.state_specs(pspecs, _zero_flat_params(params, zp, True))


def make_train_state(model, optimizer, rng=None, abstract: bool = False,
                     cfg: TrainCfg = TrainCfg(), mesh=None):
    """{"params", "opt", "step"[, "ef"]} pytree.  With ``cfg.zero`` the
    optimizer state is laid out over FLAT padded leaves (see
    ``_zero_flat_params``) sharded on the data axis — ``mesh=`` is then
    required because the padding depends on the data-parallel size."""
    if abstract:
        params = model.abstract_params()
        opt_params = (_zero_flat_params(params, zero_layout(cfg, mesh)[1],
                                        True) if cfg.zero else params)
        opt = jax.eval_shape(optimizer.init, opt_params)
        step = jax.ShapeDtypeStruct((), jnp.int32)
    else:
        params = model.init(rng if rng is not None else jax.random.PRNGKey(0))
        opt_params = (_zero_flat_params(params, zero_layout(cfg, mesh)[1],
                                        False) if cfg.zero else params)
        opt = optimizer.init(opt_params)
        step = jnp.zeros((), jnp.int32)
    state = {"params": params, "opt": opt, "step": step}
    if cfg.sync_mode == "compressed":
        if cfg.bucket_grads:
            state["ef"] = bucket_ef_zeros(grad_bucket_plan(params, cfg),
                                          abstract=abstract)
        else:
            mk = (lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)) \
                if abstract else (lambda p: jnp.zeros(p.shape, jnp.float32))
            state["ef"] = jax.tree_util.tree_map(mk, params)
    return state


def state_specs(model, optimizer, cfg: TrainCfg = TrainCfg(), mesh=None
                ) -> Dict[str, Any]:
    ps = model.param_specs()
    opt_specs = (_zero_opt_specs(model, optimizer, cfg, mesh) if cfg.zero
                 else optimizer.state_specs(ps, model.abstract_params()))
    specs = {"params": ps,
             "opt": opt_specs,
             "step": P()}
    if cfg.sync_mode == "compressed":
        if cfg.bucket_grads:
            specs["ef"] = tuple(
                P() for _ in grad_bucket_plan(model.abstract_params(), cfg))
        else:
            specs["ef"] = ps
    return specs


def batch_specs(batch: Dict[str, Any], data_axes=("pod", "data")
                ) -> Dict[str, P]:
    """Batch sharding: batch dim over the data axes.  M-RoPE ``positions``
    are (3, B, S) — batch at dim 1."""
    def one(path, _):
        name = path[-1].key if path else ""
        if name == "positions":
            return P(None, data_axes)
        return P(data_axes)
    return jax.tree_util.tree_map_with_path(one, batch)


# ---------------------------------------------------------------------------
# Grad accumulation over microbatches
# ---------------------------------------------------------------------------

def _split_micro(batch: Dict[str, jax.Array], n: int) -> Dict[str, jax.Array]:
    def one(path, x):
        name = path[-1].key if path else ""
        if name == "positions":              # (3, B, S) -> (n, 3, B/n, S)
            y = x.reshape((x.shape[0], n, x.shape[1] // n) + x.shape[2:])
            return jnp.moveaxis(y, 1, 0)
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])
    return jax.tree_util.tree_map_with_path(one, batch)


def _accumulate_grads(loss_fn: Callable, params, batch, n_micro: int,
                      grad_dtype, peel_last: bool = False
                      ) -> Tuple[jax.Array, Params]:
    """Microbatched gradient accumulation.

    ``peel_last=True`` peels the final microbatch out of the scan body
    into straight-line code: a collective started right after the scan
    then overlaps the peeled backward pass (XLA cannot interleave ops
    into a scan, so without the peel every gradient sync waits for the
    whole accumulation loop).  The peeled iteration performs the exact
    same op sequence as the in-scan one, so losses stay bit-identical.
    """
    if n_micro == 1:
        (loss, _), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, grads

    micro = _split_micro(batch, n_micro)

    def body(carry, mb):
        loss_acc, grads_acc = carry
        (loss, _), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb)
        grads_acc = jax.tree_util.tree_map(
            lambda a, g: a + g.astype(grad_dtype), grads_acc, grads)
        return (loss_acc + loss, grads_acc), None

    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, grad_dtype), params)
    init = (jnp.zeros((), jnp.float32), zeros)
    if peel_last:
        head = jax.tree_util.tree_map(lambda x: x[:-1], micro)
        tail = jax.tree_util.tree_map(lambda x: x[-1], micro)
        carry, _ = jax.lax.scan(body, init, head)
        (loss_sum, grads_sum), _ = body(carry, tail)
    else:
        (loss_sum, grads_sum), _ = jax.lax.scan(body, init, micro)
    inv = 1.0 / n_micro
    grads = jax.tree_util.tree_map(lambda g: g * inv, grads_sum)
    return loss_sum * inv, grads


# ---------------------------------------------------------------------------
# Gradient sync flavours (both route mean-scaling through comm.mean_scale)
# ---------------------------------------------------------------------------

def _bucket_sync(dcomm: "comm_mod.Communicator", grads, compress, ef,
                 bucket_bytes):
    """Fused dtype-grouped buckets: amortizes the alpha term across each
    bucket's leaves while keeping bf16 gradients bf16 on the wire."""
    return dcomm.sync_gradients_bucketed(
        grads, mean=True, bucket_bytes=bucket_bytes,
        compress=compress, ef_state=ef)


def _leaf_sync(dcomm: "comm_mod.Communicator", axis_comms, grads, compress,
               ef_tree):
    if not compress:
        synced, _ = dcomm.sync_gradients(grads, mean=True)
        return synced, ef_tree
    ef_states = jax.tree_util.tree_map(lambda r: EFState(residual=r), ef_tree)
    synced, new_states = axis_comms[0].sync_gradients(
        grads, mean=True, compress=True, ef_state=ef_states)
    for acomm in axis_comms[1:]:
        synced = jax.tree_util.tree_map(
            lambda g, _c=acomm: _c.all_reduce(g, mean=True), synced)
    new_ef = jax.tree_util.tree_map(
        lambda s: s.residual, new_states,
        is_leaf=lambda x: isinstance(x, EFState))
    return synced, new_ef


# ---------------------------------------------------------------------------
# Overlapped (nonblocking start/wait) gradient sync — schedule IR
#
# Since PR 6 the overlapped sync is not hand-sequenced: the communicator
# builds the canonical *blocking* program (``comm.sync_schedule``), the
# planner's pass pipeline rewrites it (reverse layout order, depth-N
# interleaving, start hoisting across the peeled microbatch), and
# ``schedule.execute`` turns op order into start/progress/wait calls.
# ``overlap_depth=2`` reproduces the old hand-scheduled pipeline op for
# op — start unit i, then wait its already-started neighbour, no progress
# hops — so per-unit arithmetic (stage split, scale, EF update) is
# identical to the blocking paths and losses stay bit-identical.
# ``overlap_depth>=3`` keeps more transfers live and drains wait-phase
# protocol stages early via per-stage ``progress`` hops (*MPI Progress
# For All*); each unit's hop chain is unchanged, only its placement.
# ---------------------------------------------------------------------------


def _overlap_sync_schedule(ucomm, specs, compress, depth, compute=()):
    """Blocking sync program → canonical overlap pass pipeline."""
    base = ucomm.sync_schedule(specs, compress=compress, compute=compute)
    sched, timings = plan_mod.run_passes(
        base, plan_mod.canonical_overlap_passes(depth))
    sched.meta["depth"] = depth
    sched.meta["pass_us"] = timings
    return sched


def _bucket_sync_overlapped(dcomm, axis_comms, handles, buckets, grads,
                            compress, ef, sched=None, depth=2):
    """Overlapped twin of ``_bucket_sync``: uncompressed buckets go
    through pre-bound persistent handles (one revocation check per start),
    compressed buckets through the communicator's planned two-phase sync
    (the EF residual mutates in its wait arm, nowhere else)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    out = [None] * len(leaves)
    new_ef = [None] * len(buckets)
    if compress:
        # same layout contract (and the same actionable error) as the
        # blocking engine.sync_gradients_bucketed path
        if ef is None:
            ef = bucket_ef_zeros(buckets)
        elif (len(ef) != len(buckets)
              or any(e.shape[-1] != b.size for e, b in zip(ef, buckets))):
            raise ValueError(
                f"ef_state layout {[e.shape[-1] for e in ef]} does not "
                f"match the bucket plan {[b.size for b in buckets]} — was "
                f"it built with the same bucket_bytes?")
    if sched is None:
        sched = _overlap_sync_schedule(
            dcomm, [(f"bucket{i}", b.size, b.wire_dtype)
                    for i, b in enumerate(buckets)], compress, depth)

    def start(u):
        flat = plan_mod.gather_bucket(leaves, buckets[u.index])
        if compress:
            # mean=False: the blocking bucketed path applies ONE full-axes
            # scale after the cross-axis reductions — replicated below so
            # the float op order (and hence the loss bits) match exactly.
            return axis_comms[0].sync_gradient_start(
                flat, mean=False, compress=True, ef_residual=ef[u.index])
        return handles[u.index].start(flat)

    def progress(u, tok, stages):
        if compress:
            axis_comms[0].sync_gradient_progress(tok, stages)
        else:
            handles[u.index].progress(tok, stages)
        return tok

    def wait(u, tok):
        bi = u.index
        if compress:
            y, res = axis_comms[0].sync_gradient_wait(tok)
            for acomm in axis_comms[1:]:
                y = acomm.all_reduce(y)
            y = y * jnp.asarray(dcomm.mean_scale(), y.dtype)
            new_ef[bi] = res
        else:
            y = handles[bi].wait(tok)
        plan_mod.scatter_bucket(y, buckets[bi], out)
        return y

    schedule_mod.execute(sched, start=start, wait=wait, progress=progress)
    return (jax.tree_util.tree_unflatten(treedef, out),
            tuple(new_ef) if compress else ef)


def _leaf_sync_overlapped(dcomm, axis_comms, grads, compress, ef_tree,
                          sched=None, depth=2):
    """Overlapped twin of ``_leaf_sync``: one two-phase sync per leaf,
    schedule-IR sequenced."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    out = [None] * len(leaves)
    if compress:
        ef_leaves = treedef.flatten_up_to(ef_tree)
        new_ef = [None] * len(leaves)
    if sched is None:
        sched = _overlap_sync_schedule(
            dcomm, [(f"leaf{i}", l.size, l.dtype)
                    for i, l in enumerate(leaves)], compress, depth)

    def start(u):
        i = u.index
        if compress:
            return axis_comms[0].sync_gradient_start(
                leaves[i], compress=True, ef_residual=ef_leaves[i])
        return dcomm.sync_gradient_start(leaves[i])

    def progress(u, tok, stages):
        comm = axis_comms[0] if compress else dcomm
        comm.sync_gradient_progress(tok, stages)
        return tok

    def wait(u, tok):
        i = u.index
        if compress:
            y, res = axis_comms[0].sync_gradient_wait(tok)
            for acomm in axis_comms[1:]:
                y = acomm.all_reduce(y, mean=True)
            new_ef[i] = res
        else:
            y, _ = dcomm.sync_gradient_wait(tok)
        out[i] = y
        return y

    schedule_mod.execute(sched, start=start, wait=wait, progress=progress)
    synced = jax.tree_util.tree_unflatten(treedef, out)
    if not compress:
        return synced, ef_tree
    return synced, jax.tree_util.tree_unflatten(treedef, new_ef)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def make_train_step(model, optimizer, cfg: TrainCfg = TrainCfg(),
                    mesh=None, engine=None,
                    comm: Optional["comm_mod.Communicator"] = None
                    ) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    Composed/compressed modes need a communicator: pass ``comm=``
    (normally ``session.world`` from a ``repro.comm.Session``).  The
    legacy ``mesh=``+``engine=`` pair still works and is adopted into a
    communicator internally."""

    def loss_fn(p, b):
        return model.loss(p, b)

    if cfg.sync_mode == "auto":
        def train_step(state, batch):
            loss, grads = _accumulate_grads(
                loss_fn, state["params"], batch, cfg.microbatches,
                cfg.grad_dtype)
            new_params, new_opt, om = optimizer.update(
                grads, state["opt"], state["params"])
            return ({"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}, {"loss": loss, **om})
        train_step.schedule = None
        train_step.ag_schedule = None
        train_step.schedule_pass_us = {}
        return train_step

    if cfg.sync_mode not in ("composed", "compressed"):
        raise ValueError(cfg.sync_mode)
    if comm is None:
        if mesh is None or engine is None:
            raise ValueError("composed mode needs comm= (repro.comm "
                             "Communicator) or the legacy mesh= + engine=")
        comm = comm_mod.Session.adopt(engine, mesh).world
    if mesh is None:
        mesh = comm.mesh
    if mesh is None:
        raise ValueError("the communicator's session has no mesh; "
                         "pass mesh= explicitly")

    compress = cfg.sync_mode == "compressed"
    data_axes = tuple(a for a in cfg.data_axes if a in mesh.axis_names)
    if not data_axes:
        raise ValueError(
            f"sync_mode={cfg.sync_mode!r} has nothing to sync over: none "
            f"of cfg.data_axes={cfg.data_axes} exist in the mesh axes "
            f"{tuple(mesh.axis_names)}")
    manual = set(data_axes)
    dcomm = comm.split(*data_axes)
    # per-axis sub-communicators: the loss reduction and the compressed
    # path's cross-axis stage are sequential single-axis collectives.
    axis_comms = tuple(comm.split(a) for a in data_axes)

    # Overlapped mode: the bucket layout is static in (param shapes,
    # dtypes, bucket_bytes), so uncompressed buckets get persistent
    # handles bound ONCE here — protocol + tier + mean scale resolved at
    # build time, a start is one revocation check.  sync_stats=True makes
    # each start record its wire bytes under the engine's sync key
    # exactly like the blocking planned path (the CommStats parity fix).
    overlap = bool(cfg.overlap)
    depth = int(cfg.overlap_depth)
    peel = cfg.overlap_peel
    if peel is None:
        peel = jax.default_backend() != "cpu"
    peel = overlap and bool(peel)
    buckets = ()
    bucket_handles = ()
    sched = None
    if overlap and cfg.bucket_grads:
        buckets = grad_bucket_plan(model.abstract_params(), cfg)
        if not compress:
            bucket_handles = tuple(
                dcomm.persistent("all_reduce", (b.size,), b.wire_dtype,
                                 mean=True, sync_stats=True)
                for b in buckets)
    if overlap and not cfg.zero:
        # the work-unit layout is static in (param shapes, dtypes,
        # bucket_bytes), so the sync program is built + rewritten ONCE
        # here; every traced step executes the same schedule.
        if cfg.bucket_grads:
            specs = [(f"bucket{i}", b.size, b.wire_dtype)
                     for i, b in enumerate(buckets)]
        else:
            specs = [(f"leaf{i}", math.prod(s.shape), s.dtype)
                     for i, s in enumerate(_grad_structs(
                         model.abstract_params(), cfg))]
        tags = (("peeled_microbatch", True),) if peel else ()
        sched = _overlap_sync_schedule(dcomm, specs, compress, depth,
                                       compute=tags)

    # ZeRO-1: two persistent arms per leaf (RS of the grad, AG of the
    # updated param chunk) plus the two schedule-IR programs sequencing
    # them.  All of it is static in (param shapes, dtypes, DP size), so
    # it is built ONCE here; the optimizer update sits between the two
    # programs, which is why they cannot be one schedule.
    zero = bool(cfg.zero)
    rs_handles = ag_handles = ()
    rs_sched = ag_sched = None
    zstate_specs = None
    if zero:
        zax, zp = zero_layout(cfg, mesh)
        zcomm = axis_comms[0]            # == dcomm: single data axis
        params_abs = model.abstract_params()
        pleaves_abs = jax.tree_util.tree_leaves(params_abs)
        gstructs = _grad_structs(params_abs, cfg)
        chunk_sizes = [_zero_pad_len(g.size, zp) // zp for g in gstructs]
        rs_handles = tuple(
            zcomm.persistent("reduce_scatter", g.shape, g.dtype,
                             mean=True, sync_stats=True, zero=True)
            for g in gstructs)
        ag_handles = tuple(
            zcomm.persistent("all_gather", (csz,), l.dtype, zero=True)
            for csz, l in zip(chunk_sizes, pleaves_abs))
        rs_specs = [(f"leaf{i}", math.prod(g.shape), g.dtype)
                    for i, g in enumerate(gstructs)]
        ag_specs = [(f"param{i}", csz * zp, l.dtype)
                    for i, (csz, l) in enumerate(zip(chunk_sizes,
                                                     pleaves_abs))]
        tags = (("peeled_microbatch", True),) if peel else ()
        rs_sched = zcomm.zero_sync_schedule(rs_specs, kind="rs",
                                            compute=tags)
        # the AG's compute op models the NEXT step's forward: the
        # interleave/hoist passes place AG starts before it so the
        # gather drains under compute the model says is there.
        ag_sched = zcomm.zero_sync_schedule(
            ag_specs, kind="ag", compute=(("next_forward", True),))
        if overlap:
            rs_sched, rs_us = plan_mod.run_passes(
                rs_sched, plan_mod.canonical_overlap_passes(depth))
            ag_sched, ag_us = plan_mod.run_passes(
                ag_sched, plan_mod.canonical_overlap_passes(depth))
            rs_sched.meta["depth"] = ag_sched.meta["depth"] = depth
            rs_sched.meta["pass_us"] = rs_us
            ag_sched.meta["pass_us"] = ag_us
        # optimizer state is data-axis sharded: its specs (not P()) go
        # into the step's shard_map so each rank holds 1/N of it.  The
        # substrate's spec trees are leaf-wise (no subtree prefixes), so
        # the replicated params get a per-leaf P() tree.
        zstate_specs = {"params": jax.tree_util.tree_map(lambda _: P(),
                                                         params_abs),
                        "opt": _zero_opt_specs(model, optimizer, cfg, mesh),
                        "step": P()}

    def _zero_inner(st, loss, grads):
        """The ZeRO-1 step body (runs inside the manual shard_map):
        RS-schedule the grads down to this rank's chunks, update the
        local state shard, AG-schedule the new param chunks back up."""
        gleaves, gdef = jax.tree_util.tree_flatten(grads)
        chunks = [None] * len(gleaves)

        def rs_start(u):
            return rs_handles[u.index].start(gleaves[u.index])

        def rs_progress(u, tok, stages):
            rs_handles[u.index].progress(tok, stages)
            return tok

        def rs_wait(u, tok):
            chunks[u.index] = rs_handles[u.index].wait(tok)
            return chunks[u.index]

        schedule_mod.execute(rs_sched, start=rs_start, wait=rs_wait,
                             progress=rs_progress)
        for acomm in axis_comms:
            loss = acomm.all_reduce(loss)
        loss = loss * dcomm.mean_scale()
        # global grad norm from shard-local partial sums + ONE scalar
        # all-reduce (the unsharded path reduces over full leaves; same
        # value up to float summation order, so bit-identity of the
        # LOSSES needs clip_norm=0, where the norm is metric-only).
        sq = sum(jnp.sum(jnp.square(ch.astype(jnp.float32)))
                 for ch in chunks)
        gsq = zcomm.all_reduce(sq)

        def gnorm_fn(_tree, _n=gsq):
            return jnp.sqrt(_n)

        idx = zcomm.axis_index()
        pleaves = jax.tree_util.tree_leaves(st["params"])
        # Re-constrain the param read replicated over the auto axes: the
        # forward's activation hints shard some leaves (embed/lm_head/
        # mlp/final-norm) over "model", and feeding those into the
        # pad/slice/all-gather chain unconstrained miscompiles under the
        # legacy partitioner (see substrate._vmap_shard_map).
        pchunks = [_zero_chunk(shard_hint(l, P()), zp, idx)
                   for l in pleaves]
        new_pc, new_opt, om = optimizer.update(
            jax.tree_util.tree_unflatten(gdef, chunks), st["opt"],
            jax.tree_util.tree_unflatten(gdef, pchunks),
            global_norm_fn=gnorm_fn)
        npc = jax.tree_util.tree_leaves(new_pc)
        fulls = [None] * len(pleaves)

        def ag_start(u):
            return ag_handles[u.index].start(npc[u.index])

        def ag_progress(u, tok, stages):
            ag_handles[u.index].progress(tok, stages)
            return tok

        def ag_wait(u, tok):
            y = ag_handles[u.index].wait(tok)
            ref = pleaves[u.index]
            fulls[u.index] = shard_hint(y[:ref.size].reshape(ref.shape),
                                        P())
            return fulls[u.index]

        schedule_mod.execute(ag_sched, start=ag_start, wait=ag_wait,
                             progress=ag_progress)
        new_params = jax.tree_util.tree_unflatten(gdef, fulls)
        return ({"params": new_params, "opt": new_opt,
                 "step": st["step"] + 1}, {"loss": loss, **om})

    def train_step(state, batch):
        bspecs = batch_specs(batch, data_axes)

        st_specs = zstate_specs if zero else P()

        @functools.partial(
            substrate.shard_map, mesh=mesh,
            in_specs=(st_specs, bspecs),
            out_specs=(st_specs, P()),
            axis_names=manual, check_vma=False)
        def inner(st, local_batch):
            # overlap: peel the last microbatch out of the scan so the
            # reverse-order bucket starts interleave with its backward.
            loss, grads = _accumulate_grads(
                loss_fn, st["params"], local_batch, cfg.microbatches,
                cfg.grad_dtype, peel_last=peel)
            if zero:
                return _zero_inner(st, loss, grads)
            ef = st.get("ef")
            if cfg.bucket_grads:
                if overlap:
                    grads, new_ef = _bucket_sync_overlapped(
                        dcomm, axis_comms, bucket_handles, buckets, grads,
                        compress, ef, sched=sched, depth=depth)
                else:
                    grads, new_ef = _bucket_sync(dcomm, grads, compress,
                                                 ef, cfg.bucket_bytes)
            elif overlap:
                grads, new_ef = _leaf_sync_overlapped(
                    dcomm, axis_comms, grads, compress, ef,
                    sched=sched, depth=depth)
            else:
                grads, new_ef = _leaf_sync(dcomm, axis_comms, grads,
                                           compress, ef)
            for acomm in axis_comms:
                loss = acomm.all_reduce(loss)
            loss = loss * dcomm.mean_scale()
            new_params, new_opt, om = optimizer.update(
                grads, st["opt"], st["params"])
            new_state = {"params": new_params, "opt": new_opt,
                         "step": st["step"] + 1}
            if compress:
                new_state["ef"] = new_ef
            return new_state, {"loss": loss, **om}

        return inner(state, batch)

    # introspection: the executed sync program + per-pass rewrite timings
    # (zero mode runs TWO programs; .schedule is the RS half, the AG half
    # hangs off .ag_schedule)
    active = rs_sched if zero else sched
    train_step.schedule = active
    train_step.ag_schedule = ag_sched
    train_step.schedule_pass_us = (dict(active.meta.get("pass_us", {}))
                                   if active is not None else {})
    return train_step


# ---------------------------------------------------------------------------
# TrainSession: one (model, optimizer, cfg) bundle, many meshes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainSession:
    """Everything about a training run that survives a re-mesh.

    The elastic controller rebuilds the mesh-bound pieces (step function,
    shardings, engine plan) after every topology change; the pieces that
    must NOT change across a recovery — model, optimizer, TrainCfg, and
    through them the state structure and bucket layout — live here so the
    launch driver and the controller construct them exactly once and the
    same way.
    """

    model: Any
    optimizer: Any
    cfg: TrainCfg = TrainCfg()

    def state_specs(self, mesh=None) -> Dict[str, Any]:
        """``mesh=`` is required with ``cfg.zero`` (state layout depends
        on the data-parallel size) and ignored otherwise."""
        return state_specs(self.model, self.optimizer, self.cfg, mesh=mesh)

    def abstract_state(self, mesh=None):
        return make_train_state(self.model, self.optimizer, abstract=True,
                                cfg=self.cfg, mesh=mesh)

    def init_state(self, rng=None, mesh=None):
        return make_train_state(self.model, self.optimizer, rng,
                                cfg=self.cfg, mesh=mesh)

    def step_fn(self, mesh=None, engine=None,
                comm: Optional["comm_mod.Communicator"] = None) -> Callable:
        """Build the topology-bound train step (pass ``comm=`` — the
        session's world communicator — or the legacy mesh+engine pair);
        called again after every re-mesh."""
        return make_train_step(self.model, self.optimizer, self.cfg,
                               mesh=mesh, engine=engine, comm=comm)

    def batch_axes(self) -> Tuple[str, ...]:
        """Axes the data pipeline shards batches over (filtered to the
        mesh's axes by the pipeline/spec machinery downstream)."""
        return tuple(self.cfg.data_axes)
