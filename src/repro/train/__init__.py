from repro.train.trainer import (TrainCfg, TrainSession, make_train_state,
                                 make_train_step)

__all__ = ["TrainCfg", "TrainSession", "make_train_state", "make_train_step"]
