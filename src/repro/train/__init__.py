from repro.train.trainer import TrainCfg, make_train_state, make_train_step

__all__ = ["TrainCfg", "make_train_state", "make_train_step"]
