"""Deterministic synthetic data pipeline, shard-aware and restart-safe.

Every batch is a pure function of (seed, step): restarts after a failure
resume mid-epoch with byte-identical data — a prerequisite for the
fault-tolerance story (checkpoint carries only the step counter).  Batches
are materialized per-shard with ``jax.make_array_from_callback``, so no
host ever builds the global (global_batch, seq) array.

The token stream is a Zipf-ish mixture with local n-gram structure (so
losses decrease during smoke training runs, unlike uniform noise).
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel.sharding import filter_spec


class SyntheticLMDataset:
    """{"tokens": (B, S) int32, "labels": (B, S) int32} batches."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, embed_dim: Optional[int] = None,
                 with_embeds: bool = False, mrope: bool = False):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.embed_dim = embed_dim
        self.with_embeds = with_embeds
        self.mrope = mrope

    # -- per-example generation (pure in (seed, step, row)) -------------

    def _rows(self, step: int, lo: int, hi: int) -> np.ndarray:
        """Rows [lo, hi) of the step's global batch."""
        out = np.empty((hi - lo, self.seq_len + 1), np.int32)
        for r in range(lo, hi):
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, r]))
            # Zipf unigrams + repeated bigram motifs for learnable structure.
            base = rng.zipf(1.3, size=self.seq_len + 1) % self.vocab_size
            motif = rng.integers(0, self.vocab_size, size=8)
            pos = rng.integers(0, max(1, self.seq_len - 8),
                               size=max(1, self.seq_len // 32))
            for p in pos:
                base[p:p + 8] = motif
            out[r - lo] = base
        return out

    def host_batch(self, step: int) -> Dict[str, np.ndarray]:
        rows = self._rows(step, 0, self.global_batch)
        batch = {"tokens": rows[:, :-1], "labels": rows[:, 1:]}
        if self.with_embeds:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, 1 << 30]))
            emb = rng.standard_normal(
                (self.global_batch, self.seq_len, self.embed_dim),
                np.float32) * 0.02
            batch["inputs_embeds"] = emb
            if self.mrope:
                pos = np.broadcast_to(
                    np.arange(self.seq_len, dtype=np.int32),
                    (3, self.global_batch, self.seq_len)).copy()
                batch["positions"] = pos
        return batch

    # -- sharded global arrays -------------------------------------------

    def sharded_batch(self, step: int, mesh,
                      batch_axes=("pod", "data")) -> Dict[str, jax.Array]:
        """Build the step's global batch directly as sharded jax Arrays."""
        spec = filter_spec(P(batch_axes), mesh.axis_names)
        host = self.host_batch(step)

        def make(name: str, arr: np.ndarray) -> jax.Array:
            sh = NamedSharding(mesh, spec if arr.ndim >= 1 else P())
            if name == "positions":            # (3, B, S): batch at dim 1
                sh = NamedSharding(
                    mesh, filter_spec(P(None, batch_axes), mesh.axis_names))
            return jax.make_array_from_callback(
                arr.shape, sh, lambda idx: arr[idx])

        return {k: make(k, v) for k, v in host.items()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.host_batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (depth-N pipeline ahead of the step)."""

    def __init__(self, fetch: Callable[[int], Any], depth: int = 2,
                 start_step: int = 0):
        self._fetch = fetch
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                item = self._fetch(step)
            except Exception as e:           # surface in the consumer
                self._q.put(e)
                return
            self._q.put(item)
            step += 1

    def __next__(self):
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
