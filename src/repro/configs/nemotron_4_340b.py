"""nemotron-4-340b [dense] — 96L d18432 96H (GQA kv=8) ff73728 vocab256000.

Squared-ReLU MLP, GQA [arXiv:2402.16819].  Full attention -> long_500k
skipped.
"""

import jax.numpy as jnp

from repro.models.layers import AttentionCfg, MLPCfg
from repro.models.transformer import LayerSpec, StageSpec, TransformerCfg

ARCH_ID = "nemotron-4-340b"
FAMILY = "dense"
SKIP_SHAPES = ("long_500k",)
USES_EMBEDS = False


def config(param_dtype=jnp.bfloat16) -> TransformerCfg:
    d = 18_432
    return TransformerCfg(
        name=ARCH_ID, d_model=d, vocab_size=256_000,
        stages=(StageSpec((LayerSpec("attn", "dense"),), repeat=96),),
        attn=AttentionCfg(d_model=d, num_heads=96, num_kv_heads=8,
                          head_dim=192, rope_theta=1e4),
        mlp=MLPCfg(d, 73_728, "squared_relu"),
        norm="layernorm",
        param_dtype=param_dtype,
    )


def reduced(param_dtype=jnp.float32) -> TransformerCfg:
    d = 64
    return TransformerCfg(
        name=ARCH_ID + "-reduced", d_model=d, vocab_size=256,
        stages=(StageSpec((LayerSpec("attn", "dense"),), repeat=2),),
        attn=AttentionCfg(d_model=d, num_heads=4, num_kv_heads=2,
                          head_dim=16),
        mlp=MLPCfg(d, 128, "squared_relu"),
        norm="layernorm",
        param_dtype=param_dtype, block_k=16,
    )
