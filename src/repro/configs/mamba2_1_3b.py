"""mamba2-1.3b [ssm] — 48L d2048, attention-free, ssm_state=128
[arXiv:2405.21060].

Pure SSD stack (no FFN blocks, as in the Mamba reference models).
Attention-free -> long_500k RUNS at O(1) decode state.
"""

import jax.numpy as jnp

from repro.models.mamba import MambaCfg
from repro.models.transformer import LayerSpec, StageSpec, TransformerCfg

ARCH_ID = "mamba2-1.3b"
FAMILY = "ssm"
SKIP_SHAPES = ()
USES_EMBEDS = False


def config(param_dtype=jnp.bfloat16) -> TransformerCfg:
    d = 2_048
    return TransformerCfg(
        name=ARCH_ID, d_model=d, vocab_size=50_280,
        stages=(StageSpec((LayerSpec("mamba", "none"),), repeat=48),),
        mamba=MambaCfg(d_model=d, d_state=128, expand=2, headdim=64,
                       chunk=256),
        tie_embeddings=True,
        param_dtype=param_dtype,
    )


def reduced(param_dtype=jnp.float32) -> TransformerCfg:
    d = 64
    return TransformerCfg(
        name=ARCH_ID + "-reduced", d_model=d, vocab_size=256,
        stages=(StageSpec((LayerSpec("mamba", "none"),), repeat=3),),
        mamba=MambaCfg(d_model=d, d_state=16, expand=2, headdim=16, chunk=8),
        tie_embeddings=True,
        param_dtype=param_dtype, block_k=16,
    )
