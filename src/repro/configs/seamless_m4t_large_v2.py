"""seamless-m4t-large-v2 [audio] — enc-dec 24L+24L d1024 16H ff8192
vocab256206 [arXiv:2308.11596].

The speech frontend (w2v-BERT conformer) is stubbed: ``input_specs``
provides precomputed frame embeddings; the system under test is the
transformer backbone.  Full attention -> long_500k skipped; decode shapes
exercise the text decoder with self+cross attention.
"""

import jax.numpy as jnp

from repro.models.encdec import EncDecCfg
from repro.models.layers import AttentionCfg, MLPCfg

ARCH_ID = "seamless-m4t-large-v2"
FAMILY = "audio"
SKIP_SHAPES = ("long_500k",)
USES_EMBEDS = True                 # encoder takes frame embeddings


def config(param_dtype=jnp.bfloat16) -> EncDecCfg:
    d = 1_024
    attn = AttentionCfg(d_model=d, num_heads=16, num_kv_heads=16,
                        head_dim=64, rope_theta=1e4)
    return EncDecCfg(
        name=ARCH_ID, d_model=d, vocab_size=256_206,
        enc_layers=24, dec_layers=24,
        attn=attn,
        cross=AttentionCfg(d_model=d, num_heads=16, num_kv_heads=16,
                           head_dim=64, causal=False),
        mlp=MLPCfg(d, 8_192, "gelu"),
        norm="layernorm",
        param_dtype=param_dtype,
    )


def reduced(param_dtype=jnp.float32) -> EncDecCfg:
    d = 64
    attn = AttentionCfg(d_model=d, num_heads=4, num_kv_heads=4, head_dim=16)
    return EncDecCfg(
        name=ARCH_ID + "-reduced", d_model=d, vocab_size=256,
        enc_layers=2, dec_layers=2,
        attn=attn,
        cross=AttentionCfg(d_model=d, num_heads=4, num_kv_heads=4,
                           head_dim=16, causal=False),
        mlp=MLPCfg(d, 128, "gelu"),
        norm="layernorm",
        param_dtype=param_dtype, block_k=16,
    )
