"""granite-34b [dense] — 88L d6144 48H (MQA kv=1) ff24576 vocab49152.

Code model, llama-style blocks with multi-query attention
[arXiv:2405.04324].  Full attention -> long_500k skipped.
"""

import jax.numpy as jnp

from repro.models.layers import AttentionCfg, MLPCfg
from repro.models.transformer import LayerSpec, StageSpec, TransformerCfg

ARCH_ID = "granite-34b"
FAMILY = "dense"
SKIP_SHAPES = ("long_500k",)
USES_EMBEDS = False


def config(param_dtype=jnp.bfloat16) -> TransformerCfg:
    d = 6_144
    return TransformerCfg(
        name=ARCH_ID, d_model=d, vocab_size=49_152,
        stages=(StageSpec((LayerSpec("attn", "dense"),), repeat=88),),
        attn=AttentionCfg(d_model=d, num_heads=48, num_kv_heads=1,
                          head_dim=128, rope_theta=1e4),
        mlp=MLPCfg(d, 24_576, "gelu"),
        param_dtype=param_dtype,
    )


def reduced(param_dtype=jnp.float32) -> TransformerCfg:
    d = 64
    return TransformerCfg(
        name=ARCH_ID + "-reduced", d_model=d, vocab_size=256,
        stages=(StageSpec((LayerSpec("attn", "dense"),), repeat=2),),
        attn=AttentionCfg(d_model=d, num_heads=4, num_kv_heads=1,
                          head_dim=16),
        mlp=MLPCfg(d, 128, "gelu"),
        param_dtype=param_dtype, block_k=16,
    )
