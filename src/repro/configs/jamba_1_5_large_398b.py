"""jamba-1.5-large-398b [hybrid] — 72L d8192 64H (GQA kv=8) ff24576
vocab65536, MoE 16e top-2, Mamba:attention 7:1 interleave
[arXiv:2403.19887].

Stage pattern: 9 scanned super-blocks of 8 layers — attention at block
index 0, Mamba elsewhere, MoE FFN on every other layer (odd indices).
SSM layers use the Mamba2/SSD block (d_state=128) — the MXU-native form
(see DESIGN §Arch-applicability for the Mamba-1 -> SSD substitution).
SSM-dominant -> long_500k RUNS.
"""

import jax.numpy as jnp

from repro.models.layers import AttentionCfg, MLPCfg
from repro.models.mamba import MambaCfg
from repro.models.moe import MoECfg
from repro.models.transformer import LayerSpec, StageSpec, TransformerCfg

ARCH_ID = "jamba-1.5-large-398b"
FAMILY = "hybrid"
SKIP_SHAPES = ()
USES_EMBEDS = False


def _pattern():
    layers = []
    for i in range(8):
        mixer = "attn" if i == 0 else "mamba"
        ffn = "moe" if i % 2 == 1 else "dense"
        layers.append(LayerSpec(mixer, ffn))
    return tuple(layers)


def config(param_dtype=jnp.bfloat16) -> TransformerCfg:
    d = 8_192
    return TransformerCfg(
        name=ARCH_ID, d_model=d, vocab_size=65_536,
        stages=(StageSpec(_pattern(), repeat=9),),
        attn=AttentionCfg(d_model=d, num_heads=64, num_kv_heads=8,
                          head_dim=128, rope_theta=1e4),
        mamba=MambaCfg(d_model=d, d_state=128, expand=2, headdim=64,
                       chunk=256),
        mlp=MLPCfg(d, 24_576, "swiglu"),
        moe=MoECfg(d_model=d, d_ff=24_576, num_experts=16, top_k=2),
        param_dtype=param_dtype,
    )


def reduced(param_dtype=jnp.float32) -> TransformerCfg:
    d = 64
    pattern = (LayerSpec("attn", "dense"), LayerSpec("mamba", "moe"),
               LayerSpec("mamba", "dense"), LayerSpec("mamba", "moe"))
    return TransformerCfg(
        name=ARCH_ID + "-reduced", d_model=d, vocab_size=256,
        stages=(StageSpec(pattern, repeat=2),),
        attn=AttentionCfg(d_model=d, num_heads=4, num_kv_heads=2,
                          head_dim=16),
        mamba=MambaCfg(d_model=d, d_state=16, expand=2, headdim=16, chunk=8),
        mlp=MLPCfg(d, 128, "swiglu"),
        moe=MoECfg(d_model=d, d_ff=64, num_experts=4, top_k=2),
        param_dtype=param_dtype, block_k=16,
    )
