"""qwen3-moe-30b-a3b [moe] — 48L d2048 32H (GQA kv=4) 128 experts top-8
(expert ff 768) vocab151936 [hf:Qwen/Qwen3-30B-A3B].

Qwen3 specifics: explicit head_dim=128, per-head q/k RMS norm, no shared
expert, normalized top-k routing.  Full attention -> long_500k skipped.
"""

import jax.numpy as jnp

from repro.models.layers import AttentionCfg
from repro.models.moe import MoECfg
from repro.models.transformer import LayerSpec, StageSpec, TransformerCfg

ARCH_ID = "qwen3-moe-30b-a3b"
FAMILY = "moe"
SKIP_SHAPES = ("long_500k",)
USES_EMBEDS = False


def config(param_dtype=jnp.bfloat16) -> TransformerCfg:
    d = 2_048
    return TransformerCfg(
        name=ARCH_ID, d_model=d, vocab_size=151_936,
        stages=(StageSpec((LayerSpec("attn", "moe"),), repeat=48),),
        attn=AttentionCfg(d_model=d, num_heads=32, num_kv_heads=4,
                          head_dim=128, qk_norm=True, rope_theta=1e6),
        moe=MoECfg(d_model=d, d_ff=768, num_experts=128, top_k=8,
                   norm_topk=True),
        param_dtype=param_dtype,
    )


def reduced(param_dtype=jnp.float32) -> TransformerCfg:
    d = 64
    return TransformerCfg(
        name=ARCH_ID + "-reduced", d_model=d, vocab_size=256,
        stages=(StageSpec((LayerSpec("attn", "moe"),), repeat=2),),
        attn=AttentionCfg(d_model=d, num_heads=4, num_kv_heads=2,
                          head_dim=16, qk_norm=True, rope_theta=1e6),
        moe=MoECfg(d_model=d, d_ff=32, num_experts=8, top_k=2),
        param_dtype=param_dtype, block_k=16,
    )
