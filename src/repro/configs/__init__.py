"""Config registry: the 10 assigned architectures × 4 input shapes.

``get_config(arch_id)`` returns the full published config;
``get_config(arch_id, reduced=True)`` the smoke-test-sized variant of the
same family (same mixers/FFN kinds/flags, tiny dims).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax.numpy as jnp

from repro.configs import (deepseek_v3_671b, granite_34b,
                           jamba_1_5_large_398b, mamba2_1_3b,
                           mistral_large_123b, nemotron_4_340b,
                           qwen2_72b, qwen2_vl_7b, qwen3_moe_30b_a3b,
                           seamless_m4t_large_v2)
from repro.configs.shapes import SHAPE_NAMES, SHAPES, Shape, get_shape

_MODULES = (
    qwen2_vl_7b, mistral_large_123b, nemotron_4_340b, qwen2_72b,
    granite_34b, jamba_1_5_large_398b, mamba2_1_3b, seamless_m4t_large_v2,
    deepseek_v3_671b, qwen3_moe_30b_a3b,
)


@dataclasses.dataclass(frozen=True)
class ArchInfo:
    arch_id: str
    family: str
    skip_shapes: Tuple[str, ...]
    uses_embeds: bool
    config: Callable
    reduced: Callable


ARCHS: Dict[str, ArchInfo] = {
    m.ARCH_ID: ArchInfo(
        arch_id=m.ARCH_ID, family=m.FAMILY, skip_shapes=m.SKIP_SHAPES,
        uses_embeds=m.USES_EMBEDS, config=m.config, reduced=m.reduced)
    for m in _MODULES
}

ARCH_IDS: Tuple[str, ...] = tuple(ARCHS)


def get_arch(arch_id: str) -> ArchInfo:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(ARCHS)}")
    return ARCHS[arch_id]


def get_config(arch_id: str, reduced: bool = False, param_dtype=None):
    info = get_arch(arch_id)
    if reduced:
        return info.reduced() if param_dtype is None \
            else info.reduced(param_dtype)
    return info.config() if param_dtype is None else info.config(param_dtype)


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; 40 total, minus per-arch skips."""
    for arch_id, info in ARCHS.items():
        for shape_name in SHAPE_NAMES:
            skipped = shape_name in info.skip_shapes
            if skipped and not include_skipped:
                continue
            yield arch_id, shape_name, skipped


__all__ = ["ARCHS", "ARCH_IDS", "ArchInfo", "SHAPES", "SHAPE_NAMES",
           "Shape", "cells", "get_arch", "get_config", "get_shape"]
