"""mistral-large-123b [dense] — 88L d12288 96H (GQA kv=8) ff28672 vocab32768.

[hf:mistralai/Mistral-Large-Instruct-2407].  Full attention -> long_500k
skipped.
"""

import jax.numpy as jnp

from repro.models.layers import AttentionCfg, MLPCfg
from repro.models.transformer import LayerSpec, StageSpec, TransformerCfg

ARCH_ID = "mistral-large-123b"
FAMILY = "dense"
SKIP_SHAPES = ("long_500k",)
USES_EMBEDS = False


def config(param_dtype=jnp.bfloat16) -> TransformerCfg:
    d = 12_288
    return TransformerCfg(
        name=ARCH_ID, d_model=d, vocab_size=32_768,
        stages=(StageSpec((LayerSpec("attn", "dense"),), repeat=88),),
        attn=AttentionCfg(d_model=d, num_heads=96, num_kv_heads=8,
                          head_dim=128, rope_theta=1e6),
        mlp=MLPCfg(d, 28_672, "swiglu"),
        param_dtype=param_dtype,
    )


def reduced(param_dtype=jnp.float32) -> TransformerCfg:
    d = 64
    return TransformerCfg(
        name=ARCH_ID + "-reduced", d_model=d, vocab_size=256,
        stages=(StageSpec((LayerSpec("attn", "dense"),), repeat=2),),
        attn=AttentionCfg(d_model=d, num_heads=4, num_kv_heads=2,
                          head_dim=16, rope_theta=1e6),
        mlp=MLPCfg(d, 128, "swiglu"),
        param_dtype=param_dtype, block_k=16,
    )
