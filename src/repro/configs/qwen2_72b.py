"""qwen2-72b [dense] — 80L d8192 64H (GQA kv=8) ff29568 vocab152064.

GQA with QKV bias [arXiv:2407.10671].  Full attention -> long_500k skipped.
"""

import jax.numpy as jnp

from repro.models.layers import AttentionCfg, MLPCfg
from repro.models.transformer import LayerSpec, StageSpec, TransformerCfg

ARCH_ID = "qwen2-72b"
FAMILY = "dense"
SKIP_SHAPES = ("long_500k",)
USES_EMBEDS = False


def config(param_dtype=jnp.bfloat16) -> TransformerCfg:
    d = 8_192
    return TransformerCfg(
        name=ARCH_ID, d_model=d, vocab_size=152_064,
        stages=(StageSpec((LayerSpec("attn", "dense"),), repeat=80),),
        attn=AttentionCfg(d_model=d, num_heads=64, num_kv_heads=8,
                          head_dim=128, qkv_bias=True, rope_theta=1e6),
        mlp=MLPCfg(d, 29_568, "swiglu"),
        param_dtype=param_dtype,
    )


def reduced(param_dtype=jnp.float32) -> TransformerCfg:
    d = 64
    return TransformerCfg(
        name=ARCH_ID + "-reduced", d_model=d, vocab_size=256,
        stages=(StageSpec((LayerSpec("attn", "dense"),), repeat=2),),
        attn=AttentionCfg(d_model=d, num_heads=4, num_kv_heads=2,
                          head_dim=16, qkv_bias=True, rope_theta=1e6),
        mlp=MLPCfg(d, 128, "swiglu"),
        param_dtype=param_dtype, block_k=16,
    )
