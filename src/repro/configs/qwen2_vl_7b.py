"""qwen2-vl-7b [vlm] — 28L d3584 28H (GQA kv=4) ff18944 vocab152064.

M-RoPE (t/h/w sections), dynamic-resolution vision frontend stubbed:
``input_specs`` feeds precomputed patch embeddings + 3D positions
[arXiv:2409.12191].  Full attention -> long_500k skipped.
"""

import jax.numpy as jnp

from repro.models.layers import AttentionCfg, MLPCfg
from repro.models.transformer import LayerSpec, StageSpec, TransformerCfg

ARCH_ID = "qwen2-vl-7b"
FAMILY = "vlm"
SKIP_SHAPES = ("long_500k",)       # pure full attention
USES_EMBEDS = True                 # stub frontend feeds inputs_embeds


def config(param_dtype=jnp.bfloat16) -> TransformerCfg:
    d, heads, kv, dh = 3584, 28, 4, 128
    return TransformerCfg(
        name=ARCH_ID, d_model=d, vocab_size=152_064,
        stages=(StageSpec((LayerSpec("attn", "dense"),), repeat=28),),
        attn=AttentionCfg(d_model=d, num_heads=heads, num_kv_heads=kv,
                          head_dim=dh, qkv_bias=True, rope_theta=1e6,
                          mrope_sections=(16, 24, 24)),
        mlp=MLPCfg(d, 18_944, "swiglu"),
        embed_inputs=False,        # patch/text embeddings arrive precomputed
        param_dtype=param_dtype,
    )


def reduced(param_dtype=jnp.float32) -> TransformerCfg:
    d = 64
    return TransformerCfg(
        name=ARCH_ID + "-reduced", d_model=d, vocab_size=256,
        stages=(StageSpec((LayerSpec("attn", "dense"),), repeat=2),),
        attn=AttentionCfg(d_model=d, num_heads=4, num_kv_heads=2, head_dim=16,
                          qkv_bias=True, rope_theta=1e6,
                          mrope_sections=(2, 3, 3)),
        mlp=MLPCfg(d, 128, "swiglu"),
        embed_inputs=False, param_dtype=param_dtype, block_k=16,
    )
