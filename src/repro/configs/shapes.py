"""The four assigned input-shape sets (seq_len × global_batch)."""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}

SHAPE_NAMES: Tuple[str, ...] = tuple(SHAPES)


def get_shape(name: str) -> Shape:
    return SHAPES[name]
