"""deepseek-v3-671b [moe] — 61L d7168 128H MLA, MoE 1 shared + 256 routed
top-8 (expert ff 2048, dense ff 18432 on the first 3 layers), MTP head,
vocab 129280 [arXiv:2412.19437].

The most technique-representative arch: expert-parallel all_to_all
dominates its collective profile.  Full attention -> long_500k skipped
(MLA cache compression helps memory, not compute scaling).
"""

import jax.numpy as jnp

from repro.models.layers import MLPCfg
from repro.models.mla import MLACfg
from repro.models.moe import MoECfg
from repro.models.transformer import LayerSpec, StageSpec, TransformerCfg

ARCH_ID = "deepseek-v3-671b"
FAMILY = "moe"
SKIP_SHAPES = ("long_500k",)
USES_EMBEDS = False


def config(param_dtype=jnp.bfloat16) -> TransformerCfg:
    d = 7_168
    return TransformerCfg(
        name=ARCH_ID, d_model=d, vocab_size=129_280,
        stages=(StageSpec((LayerSpec("mla", "dense"),), repeat=3),
                StageSpec((LayerSpec("mla", "moe"),), repeat=58)),
        mla=MLACfg(d_model=d, num_heads=128, q_lora=1_536, kv_lora=512,
                   dh_nope=128, dh_rope=64, dh_v=128),
        mlp=MLPCfg(d, 18_432, "swiglu"),
        moe=MoECfg(d_model=d, d_ff=2_048, num_experts=256, top_k=8,
                   num_shared=1, shared_d_ff=2_048, scoring="sigmoid",
                   norm_topk=True),
        mtp=True,
        param_dtype=param_dtype,
    )


def reduced(param_dtype=jnp.float32) -> TransformerCfg:
    d = 64
    return TransformerCfg(
        name=ARCH_ID + "-reduced", d_model=d, vocab_size=256,
        stages=(StageSpec((LayerSpec("mla", "dense"),), repeat=1),
                StageSpec((LayerSpec("mla", "moe"),), repeat=2)),
        mla=MLACfg(d_model=d, num_heads=4, q_lora=32, kv_lora=16,
                   dh_nope=16, dh_rope=8, dh_v=16),
        mlp=MLPCfg(d, 128, "swiglu"),
        moe=MoECfg(d_model=d, d_ff=32, num_experts=8, top_k=2,
                   num_shared=1, shared_d_ff=32, scoring="sigmoid"),
        mtp=True,
        param_dtype=param_dtype, block_k=16,
    )
