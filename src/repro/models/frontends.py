"""Stub modality frontends (per assignment: precomputed embeddings).

``[vlm]``/``[audio]`` architectures get their patch/frame embeddings from
here — deterministic pseudo-embeddings for smoke tests and examples, and
ShapeDtypeStructs for the dry-run.  The transformer backbone is the real
system under test; these stubs define its input contract.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def vision_patch_embeds(rng, batch: int, seq: int, d_model: int,
                        dtype=jnp.float32) -> Dict[str, jax.Array]:
    """Qwen2-VL stub: patch embeddings + 3D M-RoPE positions (t, h, w).

    Emulates dynamic-resolution output: a prefix of image patches laid out
    on a (t=1, h, w) grid followed by text positions continuing from the
    image span — the shape contract of Qwen2-VL's vision merger.
    """
    embeds = jax.random.normal(rng, (batch, seq, d_model), jnp.float32)
    embeds = (embeds * 0.02).astype(dtype)
    n_img = seq // 4                       # leading quarter is "image"
    side = max(int(n_img ** 0.5), 1)
    idx = jnp.arange(seq)
    in_img = idx < n_img
    t_pos = jnp.where(in_img, 0, idx - n_img + side)
    h_pos = jnp.where(in_img, jnp.minimum(idx // side, side - 1),
                      idx - n_img + side)
    w_pos = jnp.where(in_img, idx % side, idx - n_img + side)
    pos = jnp.stack([t_pos, h_pos, w_pos])             # (3, S)
    positions = jnp.broadcast_to(pos[:, None, :], (3, batch, seq))
    return {"inputs_embeds": embeds, "positions": positions}


def vision_input_specs(batch: int, seq: int, d_model: int, dtype=jnp.bfloat16
                       ) -> Dict[str, jax.ShapeDtypeStruct]:
    return {
        "inputs_embeds": jax.ShapeDtypeStruct((batch, seq, d_model), dtype),
        "positions": jax.ShapeDtypeStruct((3, batch, seq), jnp.int32),
    }


def audio_frame_embeds(rng, batch: int, frames: int, d_model: int,
                       dtype=jnp.float32) -> jax.Array:
    """Seamless stub: w2v-BERT-style frame embeddings (already downsampled)."""
    x = jax.random.normal(rng, (batch, frames, d_model), jnp.float32)
    return (x * 0.05).astype(dtype)


def audio_input_specs(batch: int, frames: int, d_model: int,
                      dtype=jnp.bfloat16) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, frames, d_model), dtype)
