"""Unified decoder LM: dense / MoE / MLA / Mamba / hybrid interleaves.

A model is a sequence of *stages*; each stage is an unrolled pattern of
layers (`LayerSpec`s) scanned ``repeat`` times with stacked params — the
whole 61-to-96-layer model lowers to a handful of ``lax.scan`` ops, which
keeps AOT compile time flat across the 40 dry-run cells.

Layer = pre-norm mixer (attn | mla | mamba) + optional pre-norm FFN
(dense | moe), both residual.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import mamba as M
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.parallel.sharding import activation_hint, shard_hint, stack_specs

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"            # attn | mla | mamba
    ffn: str = "dense"             # dense | moe | none


@dataclasses.dataclass(frozen=True)
class StageSpec:
    layers: Tuple[LayerSpec, ...]
    repeat: int


@dataclasses.dataclass(frozen=True)
class TransformerCfg:
    name: str
    d_model: int
    vocab_size: int
    stages: Tuple[StageSpec, ...]
    attn: Optional[L.AttentionCfg] = None
    mla: Optional[MLA.MLACfg] = None
    mamba: Optional[M.MambaCfg] = None
    mlp: Optional[L.MLPCfg] = None
    moe: Optional[MOE.MoECfg] = None
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    embed_inputs: bool = True      # False: caller feeds inputs_embeds (VLM)
    mtp: bool = False              # deepseek-v3 multi-token prediction head
    mtp_loss_weight: float = 0.3
    param_dtype: Any = jnp.float32
    remat: bool = True
    remat_policy: str = "nothing"  # nothing | dots
    block_k: int = 512             # attention kv block

    @property
    def num_layers(self) -> int:
        return sum(len(st.layers) * st.repeat for st in self.stages)


# ---------------------------------------------------------------------------
# Norm dispatch
# ---------------------------------------------------------------------------

def _init_norm(cfg: TransformerCfg, dtype):
    if cfg.norm == "layernorm":
        return L.init_layernorm(cfg.d_model, dtype)
    return L.init_rmsnorm(cfg.d_model, dtype)


def _norm(cfg: TransformerCfg, p, x):
    if cfg.norm == "layernorm":
        return L.layernorm(p, x)
    return L.rmsnorm(p, x)


# ---------------------------------------------------------------------------
# Single layer init/apply
# ---------------------------------------------------------------------------

def init_layer(key, cfg: TransformerCfg, spec: LayerSpec):
    km, kf = jax.random.split(key)
    dt = cfg.param_dtype
    p: Params = {}
    s: Params = {}
    p["norm_mixer"], s["norm_mixer"] = _init_norm(cfg, dt)
    if spec.mixer == "attn":
        p["attn"], s["attn"] = L.init_attention(km, cfg.attn, dt)
    elif spec.mixer == "mla":
        p["mla"], s["mla"] = MLA.init_mla(km, cfg.mla, dt)
    elif spec.mixer == "mamba":
        p["mamba"], s["mamba"] = M.init_mamba(km, cfg.mamba, dt)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn != "none":
        p["norm_ffn"], s["norm_ffn"] = _init_norm(cfg, dt)
        if spec.ffn == "dense":
            p["mlp"], s["mlp"] = L.init_mlp(kf, cfg.mlp, dt)
        elif spec.ffn == "moe":
            p["moe"], s["moe"] = MOE.init_moe(kf, cfg.moe, dt)
        else:
            raise ValueError(spec.ffn)
    return p, s


def _mixer_cache_init(cfg: TransformerCfg, spec: LayerSpec, batch: int,
                      max_len: int, dtype):
    if spec.mixer == "attn":
        return L.init_kv_cache(batch, max_len, cfg.attn, dtype)
    if spec.mixer == "mla":
        return MLA.init_mla_cache(batch, max_len, cfg.mla, dtype)
    return M.init_mamba_cache(batch, cfg.mamba, dtype)


def _mixer_cache_specs(cfg: TransformerCfg, spec: LayerSpec):
    if spec.mixer == "attn":
        return L.kv_cache_specs(cfg.attn)
    if spec.mixer == "mla":
        return MLA.mla_cache_specs()
    return M.mamba_cache_specs()


def apply_layer(params: Params, cfg: TransformerCfg, spec: LayerSpec,
                x: jax.Array, *, positions=None, q_offset=0,
                cache: Optional[Params] = None, decode: bool = False,
                chunked: bool = False, valid_len=None
                ) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = _norm(cfg, params["norm_mixer"], x)
    h = shard_hint(h, P(("pod", "data"), None, None))
    new_cache = None
    if spec.mixer == "attn":
        if decode:
            out, new_cache = L.attention_decode(
                params["attn"], cfg.attn, h, cache, positions=positions)
        else:
            out, new_cache = L.attention_forward(
                params["attn"], cfg.attn, h, positions=positions,
                q_offset=q_offset, kv_cache=cache, block_k=cfg.block_k,
                chunked=chunked, valid_len=valid_len)
    elif spec.mixer == "mla":
        if decode:
            out, new_cache = MLA.mla_decode(params["mla"], cfg.mla, h, cache)
        else:
            out, new_cache = MLA.mla_forward(
                params["mla"], cfg.mla, h, positions=positions,
                q_offset=q_offset, kv_cache=cache, block_k=cfg.block_k,
                chunked=chunked, valid_len=valid_len)
    else:
        if chunked:
            raise ValueError(
                "mamba mixers have value-dependent recurrent state and "
                "no chunked-prefill path (Model.supports_chunked_prefill "
                "gates this)")
        if decode:
            out, new_cache = M.mamba_decode(params["mamba"], cfg.mamba, h,
                                            cache)
        else:
            out, new_cache = M.mamba_forward(params["mamba"], cfg.mamba, h,
                                             cache=cache)
    x = x + out
    if spec.ffn != "none":
        h = _norm(cfg, params["norm_ffn"], x)
        if spec.ffn == "dense":
            y = L.mlp_forward(params["mlp"], cfg.mlp, h)
        else:
            y, aux = MOE.moe_apply(params["moe"], cfg.moe, h)
        x = x + y
    # Layer-boundary constraint: the scan carry (and therefore the saved
    # remat boundary stack) is sequence-sharded over the TP axis.
    x = activation_hint(x)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stage init/apply (stacked params + lax.scan)
# ---------------------------------------------------------------------------

def init_stage(key, cfg: TransformerCfg, stage: StageSpec):
    keys = jax.random.split(key, stage.repeat)

    def one(k):
        ks = jax.random.split(k, len(stage.layers))
        return {f"layer{i}": init_layer(ks[i], cfg, spec)[0]
                for i, spec in enumerate(stage.layers)}

    stacked = jax.vmap(one)(jnp.stack(keys))
    specs = {f"layer{i}": init_layer(key, cfg, spec)[1]
             for i, spec in enumerate(stage.layers)}
    return stacked, stack_specs(specs)


def apply_stage(params_stage: Params, cfg: TransformerCfg, stage: StageSpec,
                x: jax.Array, *, positions=None, q_offset=0,
                caches: Optional[Params] = None, decode: bool = False,
                chunked: bool = False, valid_len=None
                ) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Scan the stage's ``repeat`` super-blocks.  ``caches``: stacked cache
    pytree with leading dim = repeat (or None)."""

    def block(x, layer_params, layer_caches):
        new_caches = {} if layer_caches is not None else None
        aux_total = jnp.zeros((), jnp.float32)
        for i, spec in enumerate(stage.layers):
            cache_i = None if layer_caches is None \
                else layer_caches[f"layer{i}"]
            x, nc, aux = apply_layer(
                layer_params[f"layer{i}"], cfg, spec, x,
                positions=positions, q_offset=q_offset, cache=cache_i,
                decode=decode, chunked=chunked, valid_len=valid_len)
            if new_caches is not None:
                new_caches[f"layer{i}"] = nc
            aux_total = aux_total + aux
        return x, new_caches, aux_total

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        block = jax.checkpoint(block, policy=policy)

    def body(carry, xs):
        x, aux_acc = carry
        layer_params, layer_caches = xs
        x, new_caches, aux = block(x, layer_params, layer_caches)
        return (x, aux_acc + aux), new_caches

    (x, aux_total), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (params_stage, caches))
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# Whole-model init/apply
# ---------------------------------------------------------------------------

def init_params(key, cfg: TransformerCfg):
    ks = jax.random.split(key, len(cfg.stages) + 4)
    dt = cfg.param_dtype
    p: Params = {}
    s: Params = {}
    if cfg.embed_inputs:
        p["embed"] = L.embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dt)
        s["embed"] = P("model", "data")
    for i, stage in enumerate(cfg.stages):
        p[f"stage{i}"], s[f"stage{i}"] = init_stage(ks[i + 1], cfg, stage)
    p["final_norm"], s["final_norm"] = _init_norm(cfg, dt)
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(ks[-3], (cfg.d_model, cfg.vocab_size), dt)
        s["lm_head"] = P("data", "model")
    if cfg.mtp:
        p["mtp_norm1"], s["mtp_norm1"] = _init_norm(cfg, dt)
        p["mtp_norm2"], s["mtp_norm2"] = _init_norm(cfg, dt)
        p["mtp_proj"] = L.dense_init(ks[-2], (2 * cfg.d_model, cfg.d_model),
                                     dt, fan_in=2 * cfg.d_model)
        s["mtp_proj"] = P(None, "data")
        mtp_spec = cfg.stages[-1].layers[-1]
        p["mtp_block"], s["mtp_block"] = init_layer(ks[-1], cfg, mtp_spec)
    return p, s


def _embed(params, cfg: TransformerCfg, batch: Dict[str, jax.Array]
           ) -> jax.Array:
    if cfg.embed_inputs:
        h = params["embed"][batch["tokens"]]
    else:
        h = batch["inputs_embeds"].astype(cfg.param_dtype)
    return shard_hint(h, P(("pod", "data"), None, None))


def _unembed(params, cfg: TransformerCfg, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = h @ params["embed"].T
    else:
        logits = h @ params["lm_head"]
    return shard_hint(logits, P(("pod", "data"), None, "model"))


def forward(params: Params, cfg: TransformerCfg, batch: Dict[str, jax.Array],
            *, caches: Optional[Params] = None, q_offset=0,
            decode: bool = False, chunked: bool = False, valid_len=None
            ) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Returns (hidden (B,S,D), new_caches, aux_loss)."""
    h = _embed(params, cfg, batch)
    positions = batch.get("positions")
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {} if caches is not None else None
    for i in range(len(cfg.stages)):
        cache_i = None if caches is None else caches[f"stage{i}"]
        h, nc, aux = apply_stage(
            params[f"stage{i}"], cfg, cfg.stages[i], h,
            positions=positions, q_offset=q_offset, caches=cache_i,
            decode=decode, chunked=chunked, valid_len=valid_len)
        if new_caches is not None:
            new_caches[f"stage{i}"] = nc
        aux_total = aux_total + aux
    h = _norm(cfg, params["final_norm"], h)
    return h, new_caches, aux_total


def logits_fn(params: Params, cfg: TransformerCfg,
              batch: Dict[str, jax.Array]) -> jax.Array:
    h, _, _ = forward(params, cfg, batch)
    return _unembed(params, cfg, h)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token NLL in f32; labels < 0 are ignored.

    The label log-prob is extracted with a one-hot contraction, NOT
    take_along_axis: a vocab-gather over model-sharded logits would force
    GSPMD to all-gather the (B, S, V) tensor, while the one-hot product
    reduces over the sharded vocab dim in place (partial sums + a scalar
    all-reduce)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), logits.shape[-1],
                            dtype=jnp.float32)
    ll = jnp.sum(lf * onehot, axis=-1)
    nll = lse - ll
    valid = (labels >= 0) if mask is None else mask & (labels >= 0)
    nll = jnp.where(valid, nll, 0.0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)


def loss_fn(params: Params, cfg: TransformerCfg,
            batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
    """Language-model loss (+ MoE aux + optional MTP)."""
    h, _, aux = forward(params, cfg, batch)
    logits = _unembed(params, cfg, h)
    labels = batch["labels"]
    loss = cross_entropy(logits, labels)
    metrics = {"nll": loss, "aux": aux}
    if cfg.mtp and cfg.embed_inputs:
        # Predict token t+2 from h_t combined with embed(token_{t+1}).
        emb_next = params["embed"][batch["tokens"]][:, 1:]      # (B,S-1,D)
        h_in = jnp.concatenate(
            [_norm(cfg, params["mtp_norm1"], h[:, :-1]),
             _norm(cfg, params["mtp_norm2"], emb_next)], axis=-1)
        h_mtp = h_in @ params["mtp_proj"]
        mtp_spec = cfg.stages[-1].layers[-1]
        h_mtp, _, aux2 = apply_layer(params["mtp_block"], cfg, mtp_spec,
                                     h_mtp)
        logits_mtp = _unembed(params, cfg, h_mtp)
        mtp_loss = cross_entropy(logits_mtp, labels[:, 1:])
        loss = loss + cfg.mtp_loss_weight * mtp_loss
        aux = aux + aux2
        metrics["mtp"] = mtp_loss
    total = loss + aux
    metrics["loss"] = total
    return total, metrics


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def init_caches(cfg: TransformerCfg, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> Params:
    caches: Params = {}
    for i, stage in enumerate(cfg.stages):
        def one_block(_):
            return {f"layer{j}": _mixer_cache_init(cfg, spec, batch,
                                                   max_len, dtype)
                    for j, spec in enumerate(stage.layers)}
        caches[f"stage{i}"] = jax.vmap(one_block)(jnp.arange(stage.repeat))
    return caches


def cache_specs(cfg: TransformerCfg) -> Params:
    specs: Params = {}
    for i, stage in enumerate(cfg.stages):
        block = {f"layer{j}": _mixer_cache_specs(cfg, spec)
                 for j, spec in enumerate(stage.layers)}
        specs[f"stage{i}"] = stack_specs(block)
    return specs
