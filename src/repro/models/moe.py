"""Mixture-of-Experts: token-choice top-k routing with static capacity.

Dispatch is scatter-based (GShard semantics without the (T, E, C) one-hot
einsum tensor): positions-within-expert come from a cumsum over the (T·k, E)
assignment matrix, tokens beyond capacity are dropped, and the (E, C, D)
expert buffers are built with a scatter-add.  All shapes are static.

Two execution paths:
  - auto (pjit/GSPMD): the (E, C, D) buffers carry a sharding constraint
    P("model", ...) so XLA inserts the expert-parallel all_to_all — the
    conventional generic lowering (the paper's baseline).
  - composed (shard_map): ``moe_forward_ep`` runs per-device with the
    engine's per-function all_to_all protocol (Bruck vs pairwise chosen by
    the cost model) — the paper's per-function protocol applied to the
    MoE's dominant collective.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import collectives as cc
from repro.models import layers as L
from repro.runtime import substrate


@dataclasses.dataclass(frozen=True)
class MoECfg:
    d_model: int
    d_ff: int                     # per-expert (routed) intermediate size
    num_experts: int
    top_k: int
    num_shared: int = 0           # deepseek-v3: 1 shared expert
    shared_d_ff: int = 0          # 0 -> d_ff
    capacity_factor: float = 1.25
    activation: str = "swiglu"
    scoring: str = "softmax"      # softmax | sigmoid (deepseek-v3)
    norm_topk: bool = True        # renormalize weights over the chosen k
    aux_loss_coef: float = 0.001


def init_moe(key, cfg: MoECfg, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    std = 1.0 / math.sqrt(D)
    stdf = 1.0 / math.sqrt(F)
    p: Dict[str, Any] = {
        "router": L.dense_init(ks[0], (D, E), jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, D, F), jnp.float32)
                   * std).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, D, F), jnp.float32)
                 * std).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, F, D), jnp.float32)
                   * stdf).astype(dtype),
    }
    s: Dict[str, Any] = {
        "router": P("data", None),
        "w_gate": P("model", "data", None),
        "w_up": P("model", "data", None),
        "w_down": P("model", None, "data"),
    }
    if cfg.num_shared:
        sf = cfg.shared_d_ff or cfg.d_ff
        mcfg = L.MLPCfg(D, sf * cfg.num_shared, cfg.activation)
        p["shared"], s["shared"] = L.init_mlp(ks[4], mcfg, dtype)
    return p, s


def capacity_of(tokens: int, cfg: MoECfg) -> int:
    c = int(math.ceil(tokens * cfg.top_k / cfg.num_experts
                      * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly tiles


def _router_probs(logits: jax.Array, cfg: MoECfg) -> jax.Array:
    if cfg.scoring == "sigmoid":
        return jax.nn.sigmoid(logits)
    return jax.nn.softmax(logits, axis=-1)


def route(x2d: jax.Array, router_w: jax.Array, cfg: MoECfg, capacity: int):
    """x2d: (T, D) -> dispatch plan + aux loss.

    Returns (expert_idx (T,k), weights (T,k), pos (T,k), keep (T,k), aux).
    """
    T = x2d.shape[0]
    logits = (x2d.astype(jnp.float32) @ router_w).astype(jnp.float32)
    probs = _router_probs(logits, cfg)
    top_vals, top_idx = jax.lax.top_k(probs, cfg.top_k)      # (T, k)
    if cfg.norm_topk:
        top_vals = top_vals / jnp.maximum(
            jnp.sum(top_vals, -1, keepdims=True), 1e-9)

    # Position of each (token, choice) within its expert: token-major order.
    onehot = jax.nn.one_hot(top_idx.reshape(-1), cfg.num_experts,
                            dtype=jnp.int32)                 # (T*k, E)
    pos1 = jnp.cumsum(onehot, axis=0) * onehot               # 1-based
    pos = jnp.sum(pos1, axis=-1) - 1                         # (T*k,)
    keep = pos < capacity
    pos = pos.reshape(T, cfg.top_k)
    keep = keep.reshape(T, cfg.top_k)

    # Switch-style load-balance auxiliary loss.
    me = jnp.mean(jax.nn.softmax(logits, -1), axis=0)        # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(top_idx[:, 0], cfg.num_experts, dtype=jnp.float32),
        axis=0)
    aux = cfg.aux_loss_coef * cfg.num_experts * jnp.sum(me * ce)
    return top_idx, top_vals, pos, keep, aux


def _expert_ffn(params, cfg: MoECfg, buf: jax.Array) -> jax.Array:
    """buf: (E, C, D) -> (E, C, D), batched over experts."""
    if cfg.activation == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
        h = jax.nn.silu(g) * u
    elif cfg.activation == "squared_relu":
        h = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
        h = jax.nn.relu(h)
        h = h * h
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, params["w_up"]))
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"])


def moe_forward(params, cfg: MoECfg, x: jax.Array,
                constraint: Optional[Callable] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Auto-parallel path.  x: (B, S, D) -> (out, aux_loss).

    ``constraint(tensor, spec)`` applies with_sharding_constraint under
    pjit (None = no constraint, e.g. in single-device smoke tests).
    """
    b, s, d = x.shape
    x2d = x.reshape(-1, d)
    T = x2d.shape[0]
    C = capacity_of(T, cfg)
    top_idx, top_vals, pos, keep, aux = route(x2d, params["router"], cfg, C)

    # Scatter tokens into per-expert buffers — one scatter per choice j, so
    # the (T·k, D) repeat of every token embedding is never materialized.
    posc = jnp.clip(pos, 0, C - 1)
    buf = jnp.zeros((cfg.num_experts, C, d), x.dtype)
    for j in range(cfg.top_k):
        contrib = x2d * keep[:, j:j + 1].astype(x.dtype)
        buf = buf.at[top_idx[:, j], posc[:, j]].add(contrib)
    if constraint is not None:
        buf = constraint(buf, P("model", None, None))

    out_buf = _expert_ffn(params, cfg, buf)
    if constraint is not None:
        out_buf = constraint(out_buf, P("model", None, None))

    # Gather back with routing weights, again per choice.
    y = jnp.zeros((T, d), x.dtype)
    for j in range(cfg.top_k):
        g = out_buf[top_idx[:, j], posc[:, j]] \
            * keep[:, j:j + 1].astype(x.dtype)
        y = y + g * top_vals[:, j:j + 1].astype(x.dtype)

    if cfg.num_shared:
        sf = cfg.shared_d_ff or cfg.d_ff
        y = y + L.mlp_forward(params["shared"],
                              L.MLPCfg(d, sf * cfg.num_shared,
                                       cfg.activation), x2d)
    return y.reshape(b, s, d), aux


def moe_apply(params, cfg: MoECfg, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """Entry point used by the transformer layer.

    Under an active mesh with a 'model' axis (and E % ep == 0) this runs
    the expert-parallel shard_map path — GSPMD's generic gather
    partitioning replicates the (E, C, D) combine across expert shards
    (a ~19 GB/device bomb at deepseek scale), so the EP path keeps the
    gather local to each expert shard and psums partial token outputs
    over the model axis instead.  Elsewhere (single device / vmap tests)
    it is the plain local computation."""
    from repro.parallel.sharding import active_mesh, auto_axis_names
    mesh = active_mesh()
    if mesh is not None and "model" in auto_axis_names(mesh):
        ep = dict(mesh.shape)["model"]
        if ep > 1 and cfg.num_experts % ep == 0:
            return moe_forward_shardmap(mesh, params, cfg, x)
    return moe_forward(params, cfg, x, constraint=None)


def moe_forward_shardmap(mesh, params, cfg: MoECfg, x: jax.Array
                         ) -> Tuple[jax.Array, jax.Array]:
    """Expert parallelism with replicated-over-TP activations.

    Device (d, m) holds tokens of data-shard d (replicated across the
    model axis) and experts [m·E/ep, (m+1)·E/ep).  Routing is computed
    redundantly per model shard (deterministic), each shard scatters only
    the tokens destined to ITS experts into a local (E/ep, C, D) buffer,
    runs its experts, gathers locally, and the partial per-token outputs
    are summed over the model axis — one psum per MoE layer, no
    all_to_all, no cross-shard gather.
    """
    import functools
    import os
    from repro.parallel.sharding import auto_axis_names
    auto = set(auto_axis_names(mesh))
    data_axes = tuple(a for a in ("pod", "data") if a in auto)
    ep = dict(mesh.shape)["model"]
    e_loc = cfg.num_experts // ep

    # Experts over "model"; the D dim stays FSDP-sharded over "data" in
    # the specs and is all-gathered INSIDE the block, so weight grads
    # leave the shard_map reduce-scattered back to (model, data) shards.
    # REPRO_MOE_FSDP=0 (ZeRO-1 layouts) keeps expert weights whole per
    # model shard: no per-call gather, grads psum over data via the
    # shard_map transpose.
    fsdp = "data" if ("data" in auto
                      and os.environ.get("REPRO_MOE_FSDP", "1") == "1") \
        else None
    pspecs: Dict[str, Any] = {
        "router": P(None, None),
        "w_gate": P("model", fsdp, None),
        "w_up": P("model", fsdp, None),
        "w_down": P("model", None, fsdp),
    }
    if cfg.num_shared:
        pspecs["shared"] = jax.tree_util.tree_map(
            lambda _: P(), params["shared"])
    bsz = 1
    for a in data_axes:
        bsz *= dict(mesh.shape)[a]
    if data_axes and x.shape[0] % bsz == 0:
        x_spec = P(data_axes if len(data_axes) > 1 else data_axes[0],
                   None, None)
    else:                    # batch=1 long-context decode: replicate tokens
        x_spec = P(None, None, None)

    @functools.partial(
        substrate.shard_map, mesh=mesh,
        in_specs=(pspecs, x_spec),
        out_specs=(x_spec, P()),
        axis_names=set(data_axes) | {"model"}, check_vma=False)
    def block(p, x_loc):
        b_loc, s, d = x_loc.shape
        x2d = x_loc.reshape(-1, d)
        T = x2d.shape[0]
        C = capacity_of(T, cfg)
        top_idx, top_vals, pos, keep, aux = route(x2d, p["router"], cfg, C)

        m_idx = cc.axis_index("model")
        e_lo = m_idx * e_loc
        posc = jnp.clip(pos, 0, C - 1)

        # FSDP: gather the experts' D dim (grads reduce-scatter back).
        pw = dict(p)
        if fsdp is not None:
            pw["w_gate"] = cc.all_gather(p["w_gate"], fsdp, dim=1)
            pw["w_up"] = cc.all_gather(p["w_up"], fsdp, dim=1)
            pw["w_down"] = cc.all_gather(p["w_down"], fsdp, dim=2)

        buf = jnp.zeros((e_loc, C, d), x_loc.dtype)
        for j in range(cfg.top_k):
            in_shard = ((top_idx[:, j] >= e_lo)
                        & (top_idx[:, j] < e_lo + e_loc) & keep[:, j])
            le = jnp.clip(top_idx[:, j] - e_lo, 0, e_loc - 1)
            contrib = x2d * in_shard[:, None].astype(x_loc.dtype)
            buf = buf.at[le, posc[:, j]].add(contrib)

        local_cfg = dataclasses.replace(cfg, num_experts=e_loc,
                                        num_shared=0)
        out_buf = _expert_ffn(pw, local_cfg, buf)

        y = jnp.zeros((T, d), x_loc.dtype)
        for j in range(cfg.top_k):
            in_shard = ((top_idx[:, j] >= e_lo)
                        & (top_idx[:, j] < e_lo + e_loc) & keep[:, j])
            le = jnp.clip(top_idx[:, j] - e_lo, 0, e_loc - 1)
            g = out_buf[le, posc[:, j]] \
                * in_shard[:, None].astype(x_loc.dtype)
            y = y + g * top_vals[:, j:j + 1].astype(x_loc.dtype)
        y = cc.psum(y, "model")

        if cfg.num_shared:
            sf = cfg.shared_d_ff or cfg.d_ff
            y = y + L.mlp_forward(p["shared"],
                                  L.MLPCfg(d, sf * cfg.num_shared,
                                           cfg.activation), x2d)
        for ax in data_axes:
            aux = cc.pmean(aux, ax)
        return y.reshape(b_loc, s, d), aux

    needed = {k: params[k] for k in pspecs}
    return block(needed, x)


def moe_forward_ep(params_local, cfg: MoECfg, x: jax.Array, *,
                   all_to_all: Callable, axis: str, ep_size: int
                   ) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel shard_map path (composed engine).

    Called per device: ``x`` is the local token shard (B_loc, S, D);
    ``params_local`` holds E/ep_size local experts.  ``all_to_all`` is the
    engine-bound protocol (tiled lax.all_to_all semantics).
    """
    b, s, d = x.shape
    x2d = x.reshape(-1, d)
    T = x2d.shape[0]
    C = capacity_of(T, cfg)
    assert cfg.num_experts % ep_size == 0
    e_loc = cfg.num_experts // ep_size
    top_idx, top_vals, pos, keep, aux = route(
        x2d, params_local["router"], cfg, C)

    flat_e = top_idx.reshape(-1)
    flat_p = jnp.clip(pos.reshape(-1), 0, C - 1)
    flat_keep = keep.reshape(-1)
    contrib = jnp.repeat(x2d, cfg.top_k, axis=0) * flat_keep[:, None]
    buf = jnp.zeros((cfg.num_experts, C, d), x.dtype)
    buf = buf.at[flat_e, flat_p].add(contrib.astype(x.dtype))

    # Dispatch: split experts across devices, gather each expert's tokens
    # from every device: (E, C, D) -> (E/p, p*C, D).
    buf = all_to_all(buf, axis, 0, 1)
    local_cfg = dataclasses.replace(cfg, num_experts=e_loc, num_shared=0)
    out_buf = _expert_ffn(params_local, local_cfg, buf)
    # Combine: inverse exchange.
    out_buf = all_to_all(out_buf, axis, 1, 0)

    gathered = out_buf[flat_e, flat_p] * flat_keep[:, None]
    weighted = gathered.reshape(T, cfg.top_k, d) \
        * top_vals[..., None].astype(x.dtype)
    y = jnp.sum(weighted, axis=1)
    if cfg.num_shared:
        sf = cfg.shared_d_ff or cfg.d_ff
        y = y + L.mlp_forward(params_local["shared"],
                              L.MLPCfg(d, sf * cfg.num_shared,
                                       cfg.activation), x2d)
    return y.reshape(b, s, d), aux
