"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Q is low-rank (d -> q_lora -> heads); KV is compressed to a per-token
latent c_kv (kv_lora) plus one shared RoPE key (dh_rope) — the KV cache
stores only (kv_lora + dh_rope) floats per token (~576 vs 2·H·Dh = 32768
for an equivalent dense MHA: 57x smaller).

Decode uses the *absorbed* formulation: the K up-projection is folded into
the query (q_nope · W_uk^T gives a query in latent space) and the V
up-projection is applied after attending over latents, so per-step decode
FLOPs scale with kv_lora, not H·Dh — this is the paper-relevant
"per-function protocol" of the attention family, and the cache stays
replicated over the TP axis while head compute shards.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class MLACfg:
    d_model: int
    num_heads: int
    q_lora: int = 1536
    kv_lora: int = 512
    dh_nope: int = 128
    dh_rope: int = 64
    dh_v: int = 128
    rope_theta: float = 1e4

    @property
    def dh_qk(self) -> int:
        return self.dh_nope + self.dh_rope


def init_mla(key, cfg: MLACfg, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    D, H = cfg.d_model, cfg.num_heads
    p = {
        "w_dq": L.dense_init(ks[0], (D, cfg.q_lora), dtype),
        "w_uq": L.dense_init(ks[1], (cfg.q_lora, H * cfg.dh_qk), dtype,
                             fan_in=cfg.q_lora),
        "w_dkv": L.dense_init(ks[2], (D, cfg.kv_lora), dtype),
        "w_kr": L.dense_init(ks[3], (D, cfg.dh_rope), dtype),
        "w_ukv": L.dense_init(ks[4], (cfg.kv_lora,
                                      H * (cfg.dh_nope + cfg.dh_v)), dtype,
                              fan_in=cfg.kv_lora),
        "w_o": L.dense_init(ks[5], (H * cfg.dh_v, D), dtype,
                            fan_in=H * cfg.dh_v),
    }
    p["q_norm"], _ = L.init_rmsnorm(cfg.q_lora, dtype)
    p["kv_norm"], _ = L.init_rmsnorm(cfg.kv_lora, dtype)
    s = {
        "w_dq": P("data", None),          # low-rank dims stay replicated
        "w_uq": P(None, "model"),         # heads shard over TP
        "w_dkv": P("data", None),
        "w_kr": P("data", None),
        "w_ukv": P(None, "model"),
        "w_o": P("model", "data"),
        "q_norm": {"scale": P(None)},
        "kv_norm": {"scale": P(None)},
    }
    return p, s


def _project_q(params, cfg: MLACfg, x, cos, sin):
    b, s, _ = x.shape
    H = cfg.num_heads
    cq = L.rmsnorm(params["q_norm"], x @ params["w_dq"])
    q = (cq @ params["w_uq"]).reshape(b, s, H, cfg.dh_qk)
    q_nope = q[..., :cfg.dh_nope]
    q_rope = L.apply_rope(q[..., cfg.dh_nope:], cos, sin)
    return q_nope, q_rope


def _latent_kv(params, cfg: MLACfg, x, cos, sin):
    """Per-token compressed latent + shared rotated key."""
    ckv = L.rmsnorm(params["kv_norm"], x @ params["w_dkv"])  # (B,S,kv_lora)
    krope = (x @ params["w_kr"])[:, :, None, :]              # (B,S,1,dh_rope)
    krope = L.apply_rope(krope, cos, sin)
    return ckv, krope[:, :, 0, :]


def mla_forward(params, cfg: MLACfg, x: jax.Array, *,
                positions: Optional[jax.Array] = None, q_offset=0,
                kv_cache: Optional[Dict[str, jax.Array]] = None,
                block_k: int = 512, chunked: bool = False,
                valid_len: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Optional[Dict]]:
    """Train/prefill path: materialize per-head K/V from the latent and run
    blockwise attention (dh_qk scores, dh_v values).

    ``chunked=True`` (paged prefill): ``q_offset`` may be traced; the
    chunk attends the full latent cache in the *absorbed* formulation
    (same math as ``mla_decode``, Sq queries at once) under an absolute
    causal mask, and ``valid_len`` clamps the length counter for chunks
    right-padded to the page boundary."""
    b, s, _ = x.shape
    H = cfg.num_heads
    if positions is None:
        positions = L.text_positions(b, s) + q_offset
    cos, sin = L.rope_cos_sin(positions, cfg.dh_rope, cfg.rope_theta)
    q_nope, q_rope = _project_q(params, cfg, x, cos, sin)
    ckv, krope = _latent_kv(params, cfg, x, cos, sin)

    new_cache = None
    if kv_cache is not None:
        new_len = kv_cache["len"] + s
        if valid_len is not None:
            new_len = jnp.minimum(new_len, valid_len)
        new_cache = {
            "ckv": jax.lax.dynamic_update_slice_in_dim(
                kv_cache["ckv"], ckv.astype(kv_cache["ckv"].dtype),
                q_offset, 1),
            "krope": jax.lax.dynamic_update_slice_in_dim(
                kv_cache["krope"], krope.astype(kv_cache["krope"].dtype),
                q_offset, 1),
            "len": new_len,
        }

    if chunked:
        assert new_cache is not None, "chunked MLA prefill needs a cache"
        out = _absorbed_attention(params, cfg, q_nope, q_rope,
                                  new_cache["ckv"], new_cache["krope"],
                                  positions)
        return out.astype(x.dtype) @ params["w_o"], new_cache

    kv = (ckv @ params["w_ukv"]).reshape(b, s, H, cfg.dh_nope + cfg.dh_v)
    k_nope, v = kv[..., :cfg.dh_nope], kv[..., cfg.dh_nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :],
                                  (b, s, H, cfg.dh_rope))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = L.flash_attention_jnp(q, k, v, causal=True, q_offset=q_offset,
                                block_k=block_k,
                                sm_scale=1.0 / math.sqrt(cfg.dh_qk))
    out = out.reshape(b, s, H * cfg.dh_v)
    return out @ params["w_o"], new_cache


def _absorbed_attention(params, cfg: MLACfg, q_nope, q_rope, ckv_c, kr_c,
                        q_pos):
    """Absorbed attention for Sq queries over the full latent cache with
    an absolute-position causal mask (``mla_decode`` generalized to
    chunks; cache positions above a query are masked, so unwritten pool
    pages never contribute)."""
    b, sq = q_nope.shape[:2]
    H = cfg.num_heads
    smax = ckv_c.shape[1]
    w_ukv = params["w_ukv"].reshape(cfg.kv_lora, H, cfg.dh_nope + cfg.dh_v)
    w_uk = w_ukv[..., :cfg.dh_nope]
    w_uv = w_ukv[..., cfg.dh_nope:]
    q_lat = jnp.einsum("bqhd,lhd->bqhl", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))           # (B,Sq,H,kv_lora)
    scale = 1.0 / math.sqrt(cfg.dh_qk)
    s_nope = jnp.einsum("bqhl,bkl->bhqk", q_lat, ckv_c.astype(jnp.float32))
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                        kr_c.astype(jnp.float32))
    s = (s_nope + s_rope) * scale                          # (B,H,Sq,Smax)
    mask = jnp.arange(smax)[None, None, :] <= q_pos[:, :, None]  # (B,Sq,Smax)
    s = jnp.where(mask[:, None, :, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out_lat = jnp.einsum("bhqk,bkl->bqhl", p, ckv_c.astype(jnp.float32))
    out = jnp.einsum("bqhl,lhd->bqhd", out_lat, w_uv.astype(jnp.float32))
    return out.reshape(b, sq, H * cfg.dh_v)


def mla_decode(params, cfg: MLACfg, x: jax.Array,
               kv_cache: Dict[str, jax.Array]
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Absorbed one-token decode: attention runs in latent space."""
    b = x.shape[0]
    H = cfg.num_heads
    pos = kv_cache["len"][:, None]                        # (B,1)
    cos, sin = L.rope_cos_sin(pos, cfg.dh_rope, cfg.rope_theta)
    q_nope, q_rope = _project_q(params, cfg, x, cos, sin)  # (B,1,H,·)
    ckv_new, krope_new = _latent_kv(params, cfg, x, cos, sin)

    idx = kv_cache["len"]
    smax = kv_cache["ckv"].shape[1]
    onehot = (jnp.arange(smax)[None, :] == idx[:, None])
    ckv_c = jnp.where(onehot[:, :, None],
                      ckv_new.astype(kv_cache["ckv"].dtype), kv_cache["ckv"])
    kr_c = jnp.where(onehot[:, :, None],
                     krope_new.astype(kv_cache["krope"].dtype),
                     kv_cache["krope"])
    new_len = idx + 1

    # Absorb W_uk into the query: q_lat[h] = q_nope[h] @ W_uk[h]^T.
    w_ukv = params["w_ukv"].reshape(cfg.kv_lora, H, cfg.dh_nope + cfg.dh_v)
    w_uk = w_ukv[..., :cfg.dh_nope]                       # (kv_lora, H, dh_n)
    w_uv = w_ukv[..., cfg.dh_nope:]                       # (kv_lora, H, dh_v)
    q_lat = jnp.einsum("bqhd,lhd->bqhl", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))          # (B,1,H,kv_lora)

    scale = 1.0 / math.sqrt(cfg.dh_qk)
    s_nope = jnp.einsum("bqhl,bkl->bhqk", q_lat,
                        ckv_c.astype(jnp.float32))
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                        kr_c.astype(jnp.float32))
    s = (s_nope + s_rope) * scale                         # (B,H,1,Smax)
    mask = jnp.arange(smax)[None, :] < new_len[:, None]
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out_lat = jnp.einsum("bhqk,bkl->bqhl", p, ckv_c.astype(jnp.float32))
    out = jnp.einsum("bqhl,lhd->bqhd", out_lat, w_uv.astype(jnp.float32))
    out = out.reshape(b, 1, H * cfg.dh_v).astype(x.dtype)
    return out @ params["w_o"], {"ckv": ckv_c, "krope": kr_c, "len": new_len}


def init_mla_cache(batch: int, max_len: int, cfg: MLACfg, dtype=jnp.bfloat16
                   ) -> Dict[str, jax.Array]:
    return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora), dtype),
            "krope": jnp.zeros((batch, max_len, cfg.dh_rope), dtype),
            "len": jnp.zeros((batch,), jnp.int32)}


def mla_cache_specs() -> Dict[str, P]:
    # The latent cache is shared by all heads: replicated over "model".
    return {"ckv": P(("pod", "data"), None, None),
            "krope": P(("pod", "data"), None, None),
            "len": P(("pod", "data"))}
