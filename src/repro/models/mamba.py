"""Mamba2 (state-space duality / SSD, arXiv:2405.21060) — chunked matmul form.

TPU adaptation: the SSD algorithm is exactly its MXU-native formulation —
the inner recurrence is re-expressed as (a) an intra-chunk "attention-like"
masked matmul S = (C·Bᵀ) ∘ decay, (b) per-chunk boundary states via
matmuls, and (c) a short scan over chunk boundaries.  Everything heavy is
a dense contraction; the sequential part is S/chunk_len steps long.

Decode is the O(1) recurrent step on a persistent (H, P, N) state —
attention-free, so the 500k-token shapes run at constant memory.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_model: int
    d_state: int = 128          # N
    expand: int = 2
    headdim: int = 64           # P
    ngroups: int = 1            # G (B/C projections shared per group)
    d_conv: int = 4
    chunk: int = 128            # SSD chunk length Q

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def nheads(self) -> int:
        return self.d_inner // self.headdim

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.ngroups * self.d_state

    @property
    def proj_width(self) -> int:
        return 2 * self.d_inner + 2 * self.ngroups * self.d_state + self.nheads


def init_mamba(key, cfg: MambaCfg, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    p = {
        "in_proj": L.dense_init(ks[0], (D, cfg.proj_width), dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, cfg.conv_channels),
                                     jnp.float32)
                   / math.sqrt(cfg.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((cfg.conv_channels,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, cfg.nheads)
                         ).astype(jnp.float32),
        "D": jnp.ones((cfg.nheads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(
                ks[2], (cfg.nheads,), jnp.float32,
                math.log(1e-3), math.log(1e-1))))),
        "out_proj": L.dense_init(ks[3], (cfg.d_inner, D), dtype,
                                 fan_in=cfg.d_inner),
    }
    p["norm"], _ = L.init_rmsnorm(cfg.d_inner, dtype)
    s = {
        "in_proj": P("data", "model"),
        "conv_w": P(None, "model"),
        "conv_b": P("model"),
        "A_log": P("model"),
        "D": P("model"),
        "dt_bias": P("model"),
        "out_proj": P("model", "data"),
        "norm": {"scale": P(None)},
    }
    return p, s


def _split_proj(cfg: MambaCfg, zxbcdt: jax.Array):
    di, gn = cfg.d_inner, cfg.ngroups * cfg.d_state
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 tail: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv1d.  xbc: (B, S, C); w: (K, C).  ``tail``:
    (B, K-1, C) state from a previous segment (decode/prefill chaining)."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    xp = jnp.concatenate([tail, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _segsum(log_a: jax.Array) -> jax.Array:
    """(..., Q) -> (..., Q, Q) with out[t, s] = sum_{r=s+1..t} log_a_r
    for t >= s, -inf above the diagonal."""
    q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    tri = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(tri, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                h0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """SSD scan in chunked matmul form.

    x: (B, S, H, P); dt: (B, S, H); A: (H,) negative; Bm/Cm: (B, S, G, N).
    h0: optional initial state (B, H, P, N).  Returns (y (B,S,H,P),
    h_final (B,H,P,N)).
    """
    b, s, h, pdim = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    nc = s // chunk
    assert nc * chunk == s, (s, chunk)

    xc = x.reshape(b, nc, chunk, h, pdim).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = Bm.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    Cc = Cm.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    Bh = jnp.repeat(Bc, rep, axis=3)          # (B,nc,Q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    la = dtc * A                               # (B,nc,Q,H) log-decay, <= 0
    la_t = jnp.moveaxis(la, -1, 2)             # (B,nc,H,Q)
    Lseg = jnp.exp(_segsum(la_t))              # (B,nc,H,Q,Q)
    xdt = xc * dtc[..., None]                  # dt folded into inputs

    # (a) intra-chunk: S_ts = (C_t . B_s) * L_ts, Y_diag = S @ xdt
    scores = jnp.einsum("bcqhn,bcshn->bchqs", Ch, Bh) * Lseg
    y_diag = jnp.einsum("bchqs,bcshp->bcqhp", scores, xdt)

    # (b) per-chunk final states: H_c = sum_s exp(sum_{r>s} la) * B_s^T xdt_s
    cs_full = jnp.cumsum(la_t, axis=-1)                    # (B,nc,H,Q)
    decay_states = jnp.exp(cs_full[..., -1:] - cs_full)    # (B,nc,H,Q)
    states = jnp.einsum("bcshn,bchs,bcshp->bchpn",
                        Bh, decay_states, xdt)             # (B,nc,H,P,N)

    # (c) inter-chunk recurrence over chunk boundaries.
    chunk_decay = jnp.exp(cs_full[..., -1])                # (B,nc,H)
    if h0 is None:
        h0 = jnp.zeros((b, h, pdim, n), jnp.float32)
    else:
        h0 = h0.astype(jnp.float32)

    def scan_fn(carry, inp):
        st, dec = inp                                      # (B,H,P,N),(B,H)
        new = carry * dec[..., None, None] + st
        return new, carry                                  # emit state BEFORE

    h_final, h_prevs = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                  # (B,nc,H,P,N)

    # (d) contribution of carried state: y_off[t] = exp(cs[t]) * C_t . H_prev
    state_decay_in = jnp.exp(cs_full)                      # (B,nc,H,Q)
    y_off = jnp.einsum("bcqhn,bchpn,bchq->bcqhp",
                       Ch, h_prevs, state_decay_in)
    y = (y_diag + y_off).reshape(b, s, h, pdim)
    return y.astype(x.dtype), h_final


def mamba_forward(params, cfg: MambaCfg, x: jax.Array, *,
                  cache: Optional[Dict[str, jax.Array]] = None
                  ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full-sequence path (train / prefill).  x: (B, S, D)."""
    b, s, d = x.shape
    zxbcdt = x @ params["in_proj"]
    z, xbc_raw, dt = _split_proj(cfg, zxbcdt)
    conv_tail = None if cache is None else cache["conv"]
    xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"], conv_tail)
    di, gn = cfg.d_inner, cfg.ngroups * cfg.d_state
    xs = xbc[..., :di].reshape(b, s, cfg.nheads, cfg.headdim)
    Bm = xbc[..., di:di + gn].reshape(b, s, cfg.ngroups, cfg.d_state)
    Cm = xbc[..., di + gn:].reshape(b, s, cfg.ngroups, cfg.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    h0 = None if cache is None else cache["ssm"]
    y, h_final = ssd_chunked(xs, dt, A, Bm, Cm, cfg.chunk, h0)
    y = y + xs.astype(jnp.float32) * params["D"][:, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = L.rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = y @ params["out_proj"]

    new_cache = None
    if cache is not None:
        tail_src = jnp.concatenate([cache["conv"], xbc_raw], axis=1)
        new_cache = {"conv": tail_src[:, -(cfg.d_conv - 1):],
                     "ssm": h_final}
    return out, new_cache


def mamba_decode(params, cfg: MambaCfg, x: jax.Array,
                 cache: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token recurrent step.  x: (B, 1, D); O(1) in sequence length."""
    b = x.shape[0]
    di, gn = cfg.d_inner, cfg.ngroups * cfg.d_state
    zxbcdt = x @ params["in_proj"]
    z, xbc_new, dt = _split_proj(cfg, zxbcdt)

    window = jnp.concatenate([cache["conv"], xbc_new], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window, params["conv_w"])
    xbc = jax.nn.silu(conv_out + params["conv_b"])[:, None, :]

    xs = xbc[..., :di].reshape(b, cfg.nheads, cfg.headdim)
    Bm = xbc[..., di:di + gn].reshape(b, cfg.ngroups, cfg.d_state)
    Cm = xbc[..., di + gn:].reshape(b, cfg.ngroups, cfg.d_state)
    rep = cfg.nheads // cfg.ngroups
    Bh = jnp.repeat(Bm, rep, axis=1)
    Ch = jnp.repeat(Cm, rep, axis=1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    a = jnp.exp(dt * -jnp.exp(params["A_log"]))             # (B,H)

    h = cache["ssm"].astype(jnp.float32)
    h = (h * a[..., None, None]
         + jnp.einsum("bhp,bhn,bh->bhpn", xs.astype(jnp.float32),
                      Bh.astype(jnp.float32), dt))
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * params["D"][:, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = L.rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = y @ params["out_proj"]
    new_cache = {"conv": window[:, 1:], "ssm": h}
    return out, new_cache


def init_mamba_cache(batch: int, cfg: MambaCfg, dtype=jnp.bfloat16
                     ) -> Dict[str, jax.Array]:
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.conv_channels), dtype),
        "ssm": jnp.zeros((batch, cfg.nheads, cfg.headdim, cfg.d_state),
                         jnp.float32),
    }


def mamba_cache_specs() -> Dict[str, P]:
    return {"conv": P(("pod", "data"), None, "model"),
            "ssm": P(("pod", "data"), "model", None, None)}
