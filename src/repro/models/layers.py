"""Shared neural layers: norms, RoPE / M-RoPE, attention, MLPs.

Conventions
-----------
- Params are nested dicts of jnp arrays; every ``init_*`` returns
  ``(params, specs)`` where ``specs`` mirrors the tree with
  ``jax.sharding.PartitionSpec`` leaves (mesh axes: "data", "model";
  cross-pod replication/batch over "pod" is added by the launcher).
- Attention defaults to the blockwise (flash) jnp algorithm — the same
  schedule as the Pallas kernel in ``repro.kernels.flash_attention`` —
  so no S×S score matrix is ever materialized in the HLO; the roofline
  memory term read off the compiled dry-run is therefore kernel-faithful.
- TP layout is Megatron-style: QKV/up projections shard the output dim
  over "model"; O/down projections shard the input dim; FSDP additionally
  shards the complementary dim over "data" (ZeRO-3).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}, {"scale": P(None)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(dim: int, dtype=jnp.float32):
    return ({"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)},
            {"scale": P(None), "bias": P(None)})


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE and M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def rope_cos_sin(positions: jax.Array, dim: int, theta: float = 1e4
                 ) -> Tuple[jax.Array, jax.Array]:
    """positions: (..., S) int -> cos/sin (..., S, dim/2) f32."""
    ang = positions[..., None].astype(jnp.float32) * rope_freqs(dim, theta)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D/2) — rotate-half convention."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(dt)


def mrope_cos_sin(positions_3d: jax.Array, dim: int, sections: Tuple[int, ...],
                  theta: float = 1e6) -> Tuple[jax.Array, jax.Array]:
    """Qwen2-VL multimodal RoPE.  positions_3d: (3, B, S) for (t, h, w);
    ``sections`` partitions dim/2 into per-component frequency bands
    (e.g. (16, 24, 24) for D=128).  Returns cos/sin (B, S, dim/2)."""
    assert sum(sections) == dim // 2, (sections, dim)
    freqs = rope_freqs(dim, theta)                       # (dim/2,)
    ang_all = positions_3d[..., None].astype(jnp.float32) * freqs  # (3,B,S,dim/2)
    parts = []
    lo = 0
    for comp, sec in enumerate(sections):
        parts.append(ang_all[comp, :, :, lo:lo + sec])
        lo += sec
    ang = jnp.concatenate(parts, axis=-1)                # (B, S, dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def text_positions(batch: int, seq: int, offset: int = 0) -> jax.Array:
    return jnp.broadcast_to(jnp.arange(seq) + offset, (batch, seq))


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------


def flash_attention_jnp(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, q_offset=0,
                        block_k: int = 512, sm_scale: float | None = None,
                        kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Blockwise-softmax attention in pure jnp (the Pallas kernel's schedule).

    q: (B, Sq, H, D); k/v: (B, Skv, Hkv, Dv); GQA folded via head grouping.
    ``q_offset``: absolute position of q[.., 0] (static int) for causal
    masking.  ``kv_len``: (B,) valid kv lengths (ragged cache).

    Forward never materializes the (Sq, Skv) score matrix, and the
    backward is a custom VJP that RECOMPUTES scores blockwise from the
    saved (q, k, v, out, lse) — the FlashAttention-2 backward.  Without
    it, differentiating the kv scan stores every block's softmax, i.e.
    the full attention matrix (a ~30 GB/device bomb at 4k train shapes).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    nblk = -(-skv // block_k)
    pad = nblk * block_k - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_len is None:
            kv_len = jnp.full((b,), skv, jnp.int32)
    if kv_len is None:
        kv_len = jnp.full((b,), skv, jnp.int32)
    if not isinstance(q_offset, (int, np.integer)):
        q_offset = int(q_offset)
    fn = _flash_vjp(causal, int(q_offset), block_k, float(scale))
    return fn(q, k, v, kv_len)


def _seq_flash_hint(x):
    """Sequence-parallel flash attention (REPRO_SEQ_FLASH=1): pin the
    query/score tiles to sequence-sharding over the TP axis.  With
    kv_heads < TP degree GSPMD cannot head-shard the score tensor and
    falls back to all-gathering it (a ~2 GB/layer tile); Sq-sharding
    keeps every tile local — each shard attends its query slice against
    the (small, replicated) KV."""
    import os
    if os.environ.get("REPRO_SEQ_FLASH", "0") != "1" or x.ndim < 3:
        return x
    from repro.parallel.sharding import shard_hint
    return shard_hint(
        x, P(("pod", "data"), "model", *([None] * (x.ndim - 2))))


def _flash_blocks(q, k, v, kv_len, causal, q_offset, block_k, scale):
    """Shared forward: returns (out f32, lse f32) with shapes
    (B,Sq,Hkv,G,Dv) / (B,Sq,Hkv,G,1).  Inputs stay in their dtype; the
    contractions accumulate in f32 via preferred_element_type."""
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    dv = v.shape[-1]
    group = h // hkv
    nblk = skv // block_k
    qg = _seq_flash_hint(q.reshape(b, sq, hkv, group, d))
    kb = jnp.moveaxis(k.reshape(b, nblk, block_k, hkv, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nblk, block_k, hkv, dv), 1, 0)
    q_pos = jnp.arange(sq) + q_offset

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, j = blk
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kblk,
                       preferred_element_type=jnp.float32) * scale
        kpos = j * block_k + jnp.arange(block_k)
        mask = (kpos[None, None, :] < kv_len[:, None, None])
        if causal:
            mask &= (q_pos[None, :, None] >= kpos[None, None, :])
        mask_e = mask[:, :, None, None, :]
        s = jnp.where(mask_e, s, -jnp.inf)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(mask_e, jnp.exp(s - m_safe), 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = alpha[..., 0][..., None] * acc + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, hkv, group, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, group, 1), jnp.float32)
    a0 = jnp.zeros((b, sq, hkv, group, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nblk)))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = acc / l_safe
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    lse = m_safe + jnp.log(l_safe)
    return out, lse


@functools.lru_cache(maxsize=None)
def _flash_vjp(causal: bool, q_offset: int, block_k: int, scale: float):
    @jax.custom_vjp
    def attn(q, k, v, kv_len):
        out, _ = _flash_blocks(q, k, v, kv_len, causal, q_offset, block_k,
                               scale)
        b, sq, hkv, group, dv = out.shape
        return out.reshape(b, sq, hkv * group, dv).astype(q.dtype)

    def fwd(q, k, v, kv_len):
        out, lse = _flash_blocks(q, k, v, kv_len, causal, q_offset, block_k,
                                 scale)
        b, sq, hkv, group, dv = out.shape
        o = out.reshape(b, sq, hkv * group, dv).astype(q.dtype)
        return o, (q, k, v, kv_len, o, lse)

    def bwd(res, do):
        q, k, v, kv_len, o, lse = res
        b, sq, h, d = q.shape
        _, skv, hkv, _ = k.shape
        dv = v.shape[-1]
        group = h // hkv
        nblk = skv // block_k
        qg = _seq_flash_hint(q.reshape(b, sq, hkv, group, d))
        og = _seq_flash_hint(
            o.reshape(b, sq, hkv, group, dv).astype(jnp.float32))
        dog = _seq_flash_hint(
            do.reshape(b, sq, hkv, group, dv).astype(jnp.float32))
        # delta_i = rowsum(dO ∘ O)  (FlashAttention-2, eq. 19)
        delta = jnp.sum(og * dog, axis=-1, keepdims=True)
        kb = jnp.moveaxis(k.reshape(b, nblk, block_k, hkv, d), 1, 0)
        vb = jnp.moveaxis(v.reshape(b, nblk, block_k, hkv, dv), 1, 0)
        q_pos = jnp.arange(sq) + q_offset

        def step(dq_acc, blk):
            kblk, vblk, j = blk
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kblk,
                           preferred_element_type=jnp.float32) * scale
            kpos = j * block_k + jnp.arange(block_k)
            mask = (kpos[None, None, :] < kv_len[:, None, None])
            if causal:
                mask &= (q_pos[None, :, None] >= kpos[None, None, :])
            mask_e = mask[:, :, None, None, :]
            p = jnp.where(mask_e, jnp.exp(s - lse), 0.0)   # recompute
            dp = jnp.einsum("bqhgd,bkhd->bqhgk", dog, vblk,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - delta) * scale
            dv_blk = jnp.einsum("bqhgk,bqhgd->bkhd",
                                p.astype(dog.dtype), dog,
                                preferred_element_type=jnp.float32)
            dk_blk = jnp.einsum("bqhgk,bqhgd->bkhd",
                                ds.astype(qg.dtype), qg,
                                preferred_element_type=jnp.float32)
            dq_acc = dq_acc + jnp.einsum(
                "bqhgk,bkhd->bqhgd", ds.astype(kblk.dtype), kblk,
                preferred_element_type=jnp.float32)
            return dq_acc, (dk_blk, dv_blk)

        dq0 = jnp.zeros((b, sq, hkv, group, d), jnp.float32)
        dq, (dk_blks, dv_blks) = jax.lax.scan(
            step, dq0, (kb, vb, jnp.arange(nblk)))
        dk = jnp.moveaxis(dk_blks, 0, 1).reshape(b, skv, hkv, d)
        dv_ = jnp.moveaxis(dv_blks, 0, 1).reshape(b, skv, hkv, dv)
        return (dq.reshape(b, sq, h, d).astype(q.dtype),
                dk.astype(k.dtype), dv_.astype(v.dtype), None)

    attn.defvjp(fwd, bwd)
    return attn


def chunk_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                    q_pos: jax.Array, sm_scale: float | None = None
                    ) -> jax.Array:
    """Chunked-prefill attention: a chunk of queries against the FULL
    cache (prior chunks + this one already written), causal-masked by
    absolute position.

    q: (B, Sq, H, D); caches: (B, Smax, Hkv, D); q_pos: (B, Sq) absolute
    positions of the queries.  Unlike ``flash_attention_jnp`` the offset
    is a *traced* value — one trace serves every chunk index, which is
    what bounds the serving tier's prefill trace count.  Cache positions
    above a query (pad tail, unwritten pages) are causal-masked, so
    page-pool garbage never leaks into the softmax.  Serving chunks are
    page-sized, so the (Sq, Smax) score tensor stays small.
    """
    b, sq, h, d = q.shape
    _, smax, hkv, _ = k_cache.shape
    group = h // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, hkv, group, d).astype(jnp.float32) * scale
    kf = k_cache.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kf)       # (B,Sq,Hkv,G,Smax)
    mask = jnp.arange(smax)[None, None, :] <= q_pos[:, :, None]
    s = jnp.where(mask[:, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array, sm_scale: float | None = None
                     ) -> jax.Array:
    """Single-token attention against a (possibly ragged) cache.

    q: (B, 1, H, D); caches: (B, Smax, Hkv, D); kv_len: (B,) valid lengths.
    Memory-bound matvec — runs as plain jnp (no kernel needed).
    """
    b, _, h, d = q.shape
    _, smax, hkv, _ = k_cache.shape
    group = h // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, group, d).astype(jnp.float32) * scale
    kf = k_cache.astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, kf)             # (B,Hkv,G,Smax)
    mask = jnp.arange(smax)[None, :] < kv_len[:, None]    # (B,Smax)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA/MQA/MHA attention layer (dense QKV path; MLA lives in models/mla.py)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttentionCfg:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False          # qwen3-style per-head RMS q/k norm
    rope_theta: float = 1e4
    mrope_sections: Optional[Tuple[int, ...]] = None  # qwen2-vl M-RoPE
    causal: bool = True
    sliding_window: Optional[int] = None


def init_attention(key, cfg: AttentionCfg, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    D, H, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p: Params = {
        "wq": dense_init(kq, (D, H * Dh), dtype),
        "wk": dense_init(kk, (D, Hkv * Dh), dtype),
        "wv": dense_init(kv, (D, Hkv * Dh), dtype),
        "wo": dense_init(ko, (H * Dh, D), dtype, fan_in=H * Dh),
    }
    s: Params = {
        "wq": P("data", "model"), "wk": P("data", "model"),
        "wv": P("data", "model"), "wo": P("model", "data"),
    }
    if cfg.qkv_bias:
        p.update({"bq": jnp.zeros((H * Dh,), dtype),
                  "bk": jnp.zeros((Hkv * Dh,), dtype),
                  "bv": jnp.zeros((Hkv * Dh,), dtype)})
        s.update({"bq": P("model"), "bk": P("model"), "bv": P("model")})
    if cfg.qk_norm:
        p["q_norm"], s["q_norm"] = init_rmsnorm(Dh, dtype)
        p["k_norm"], s["k_norm"] = init_rmsnorm(Dh, dtype)
    return p, s


def _project_qkv(params: Params, cfg: AttentionCfg, x: jax.Array):
    b, sq, _ = x.shape
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(b, sq, H, Dh)
    k = k.reshape(b, sq, Hkv, Dh)
    v = v.reshape(b, sq, Hkv, Dh)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    return q, k, v


def _rope_for(cfg: AttentionCfg, positions, batch, seq):
    if positions is None:
        positions = text_positions(batch, seq)
    if cfg.mrope_sections is not None:
        if positions.ndim == 2:      # text-only fallback: t == h == w
            positions = jnp.broadcast_to(positions, (3,) + positions.shape)
        return mrope_cos_sin(positions, cfg.head_dim, cfg.mrope_sections,
                             cfg.rope_theta)
    return rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)


def attention_forward(params: Params, cfg: AttentionCfg, x: jax.Array, *,
                      positions: Optional[jax.Array] = None,
                      q_offset=0,
                      kv_cache: Optional[Dict[str, jax.Array]] = None,
                      block_k: int = 512, chunked: bool = False,
                      valid_len: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, Optional[Dict]]:
    """Full-sequence (train/prefill) path.  Returns (out, new_cache).

    ``chunked=True`` is the paged-prefill variant: ``q_offset`` may be a
    TRACED chunk offset, queries attend the whole cache through
    ``chunk_attention`` (earlier chunks included), and ``valid_len``
    clamps the length counter so a chunk right-padded to the page
    boundary doesn't count its pad positions.
    """
    b, sq, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x)
    if positions is None:
        positions = text_positions(b, sq) + q_offset
    cos, sin = _rope_for(cfg, positions, b, sq)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    new_cache = None
    if kv_cache is not None:
        new_len = kv_cache["len"] + sq
        if valid_len is not None:
            new_len = jnp.minimum(new_len, valid_len)
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k"], k.astype(kv_cache["k"].dtype), q_offset, 1),
            "v": jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v"], v.astype(kv_cache["v"].dtype), q_offset, 1),
            "len": new_len,
        }
    if chunked:
        assert new_cache is not None, "chunked prefill needs a cache"
        q_pos = jnp.arange(sq)[None, :] + jnp.asarray(q_offset).reshape(
            (1, 1))
        q_pos = jnp.broadcast_to(q_pos, (b, sq))
        out = chunk_attention(q, new_cache["k"], new_cache["v"], q_pos)
    else:
        out = flash_attention_jnp(q, k, v, causal=cfg.causal,
                                  q_offset=q_offset, block_k=block_k)
    out = out.reshape(b, sq, cfg.num_heads * cfg.head_dim)
    return out @ params["wo"], new_cache


def attention_decode(params: Params, cfg: AttentionCfg, x: jax.Array,
                     kv_cache: Dict[str, jax.Array], *,
                     positions: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode with cache update.  x: (B, 1, D)."""
    b = x.shape[0]
    q, k, v = _project_qkv(params, cfg, x)
    pos = positions
    if pos is None:
        pos = kv_cache["len"][:, None]                    # (B, 1)
    if cfg.mrope_sections is not None and pos.ndim == 2:
        pos = jnp.broadcast_to(pos, (3,) + pos.shape)
    cos, sin = _rope_for(cfg, pos, b, 1)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    # Scatter the new kv at each sequence's own length (ragged batch).
    idx = kv_cache["len"]                                 # (B,)
    kc = _scatter_token(kv_cache["k"], k, idx)
    vc = _scatter_token(kv_cache["v"], v, idx)
    new_len = idx + 1
    out = decode_attention(q, kc, vc, new_len)
    out = out.reshape(b, 1, cfg.num_heads * cfg.head_dim)
    return out @ params["wo"], {"k": kc, "v": vc, "len": new_len}


def _scatter_token(cache: jax.Array, token: jax.Array, idx: jax.Array
                   ) -> jax.Array:
    """cache: (B, Smax, H, D); token: (B, 1, H, D); idx: (B,)."""
    b, smax = cache.shape[:2]
    onehot = (jnp.arange(smax)[None, :] == idx[:, None])  # (B, Smax)
    return jnp.where(onehot[:, :, None, None],
                     token.astype(cache.dtype), cache)


def init_kv_cache(batch: int, max_len: int, cfg: AttentionCfg,
                  dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "len": jnp.zeros((batch,), jnp.int32)}


def kv_cache_specs(cfg: AttentionCfg) -> Dict[str, P]:
    return {"k": P(("pod", "data"), None, "model", None),
            "v": P(("pod", "data"), None, "model", None),
            "len": P(("pod", "data"))}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLPCfg:
    d_model: int
    d_ff: int
    activation: str = "swiglu"     # swiglu | squared_relu | gelu


def init_mlp(key, cfg: MLPCfg, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    D, F = cfg.d_model, cfg.d_ff
    if cfg.activation == "swiglu":
        p = {"w_gate": dense_init(k1, (D, F), dtype),
             "w_up": dense_init(k2, (D, F), dtype),
             "w_down": dense_init(k3, (F, D), dtype, fan_in=F)}
        s = {"w_gate": P("data", "model"), "w_up": P("data", "model"),
             "w_down": P("model", "data")}
    else:
        p = {"w_up": dense_init(k1, (D, F), dtype),
             "w_down": dense_init(k2, (F, D), dtype, fan_in=F)}
        s = {"w_up": P("data", "model"), "w_down": P("model", "data")}
    return p, s


def mlp_forward(params: Params, cfg: MLPCfg, x: jax.Array) -> jax.Array:
    if cfg.activation == "swiglu":
        g = x @ params["w_gate"]
        u = x @ params["w_up"]
        h = jax.nn.silu(g) * u
    elif cfg.activation == "squared_relu":
        h = jax.nn.relu(x @ params["w_up"])
        h = h * h
    elif cfg.activation == "gelu":
        h = jax.nn.gelu(x @ params["w_up"])
    else:
        raise ValueError(cfg.activation)
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec decoder)
# ---------------------------------------------------------------------------


def init_cross_attention(key, cfg: AttentionCfg, dtype=jnp.float32):
    return init_attention(key, cfg, dtype)


def cross_attention_forward(params: Params, cfg: AttentionCfg,
                            x: jax.Array, memory: jax.Array,
                            block_k: int = 512) -> jax.Array:
    """x: (B, Sq, D) queries; memory: (B, Skv, D) encoder states."""
    b, sq, _ = x.shape
    skv = memory.shape[1]
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, sq, H, Dh)
    k = (memory @ params["wk"]).reshape(b, skv, Hkv, Dh)
    v = (memory @ params["wv"]).reshape(b, skv, Hkv, Dh)
    if cfg.qkv_bias:
        q = q + params["bq"].reshape(H, Dh)
        k = k + params["bk"].reshape(Hkv, Dh)
        v = v + params["bv"].reshape(Hkv, Dh)
    out = flash_attention_jnp(q, k, v, causal=False, block_k=block_k)
    out = out.reshape(b, sq, H * Dh)
    return out @ params["wo"]
