"""Encoder-decoder backbone (SeamlessM4T-v2 text/speech translator).

The modality frontend is a stub (precomputed frame embeddings —
``repro.models.frontends``); this module is the transformer backbone:
a non-causal encoder over frames and a causal decoder with cross-attention.
Both stacks scan stacked layer params like ``transformer.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import transformer as T
from repro.parallel.sharding import shard_hint, stack_specs

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class EncDecCfg:
    name: str
    d_model: int
    vocab_size: int
    enc_layers: int
    dec_layers: int
    attn: L.AttentionCfg = None          # self-attention (enc: non-causal)
    cross: L.AttentionCfg = None         # decoder cross-attention
    mlp: L.MLPCfg = None
    norm: str = "layernorm"
    param_dtype: Any = jnp.float32
    remat: bool = True
    block_k: int = 512

    @property
    def num_layers(self) -> int:
        return self.enc_layers + self.dec_layers


def _init_norm(cfg, dtype):
    if cfg.norm == "layernorm":
        return L.init_layernorm(cfg.d_model, dtype)
    return L.init_rmsnorm(cfg.d_model, dtype)


def _norm(cfg, p, x):
    return L.layernorm(p, x) if cfg.norm == "layernorm" else L.rmsnorm(p, x)


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------

def _init_enc_layer(key, cfg: EncDecCfg):
    ka, km = jax.random.split(key)
    dt = cfg.param_dtype
    enc_attn = dataclasses.replace(cfg.attn, causal=False)
    p, s = {}, {}
    p["norm1"], s["norm1"] = _init_norm(cfg, dt)
    p["attn"], s["attn"] = L.init_attention(ka, enc_attn, dt)
    p["norm2"], s["norm2"] = _init_norm(cfg, dt)
    p["mlp"], s["mlp"] = L.init_mlp(km, cfg.mlp, dt)
    return p, s


def _apply_enc_layer(params, cfg: EncDecCfg, x):
    enc_attn = dataclasses.replace(cfg.attn, causal=False)
    h = _norm(cfg, params["norm1"], x)
    h = shard_hint(h, P(("pod", "data"), None, None))
    out, _ = L.attention_forward(params["attn"], enc_attn, h,
                                 block_k=cfg.block_k)
    x = x + out
    h = _norm(cfg, params["norm2"], x)
    return x + L.mlp_forward(params["mlp"], cfg.mlp, h)


def _init_dec_layer(key, cfg: EncDecCfg):
    ka, kc, km = jax.random.split(key, 3)
    dt = cfg.param_dtype
    p, s = {}, {}
    p["norm1"], s["norm1"] = _init_norm(cfg, dt)
    p["self_attn"], s["self_attn"] = L.init_attention(ka, cfg.attn, dt)
    p["norm_x"], s["norm_x"] = _init_norm(cfg, dt)
    p["cross"], s["cross"] = L.init_cross_attention(kc, cfg.cross, dt)
    p["norm2"], s["norm2"] = _init_norm(cfg, dt)
    p["mlp"], s["mlp"] = L.init_mlp(km, cfg.mlp, dt)
    return p, s


def _apply_dec_layer(params, cfg: EncDecCfg, x, memory, *, q_offset=0,
                     cache=None, decode=False):
    h = _norm(cfg, params["norm1"], x)
    h = shard_hint(h, P(("pod", "data"), None, None))
    if decode:
        out, new_cache = L.attention_decode(params["self_attn"], cfg.attn,
                                            h, cache)
    else:
        out, new_cache = L.attention_forward(
            params["self_attn"], cfg.attn, h, q_offset=q_offset,
            kv_cache=cache, block_k=cfg.block_k)
    x = x + out
    h = _norm(cfg, params["norm_x"], x)
    x = x + L.cross_attention_forward(params["cross"], cfg.cross, h, memory,
                                      block_k=cfg.block_k)
    h = _norm(cfg, params["norm2"], x)
    return x + L.mlp_forward(params["mlp"], cfg.mlp, h), new_cache


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

def init_params(key, cfg: EncDecCfg):
    ke, kd, kt, kp = jax.random.split(key, 4)
    dt = cfg.param_dtype
    p: Params = {"embed": L.embed_init(kt, (cfg.vocab_size, cfg.d_model), dt)}
    s: Params = {"embed": P("model", "data")}

    enc_keys = jax.random.split(ke, cfg.enc_layers)
    p["encoder"] = jax.vmap(lambda k: _init_enc_layer(k, cfg)[0])(enc_keys)
    s["encoder"] = stack_specs(_init_enc_layer(ke, cfg)[1])
    dec_keys = jax.random.split(kd, cfg.dec_layers)
    p["decoder"] = jax.vmap(lambda k: _init_dec_layer(k, cfg)[0])(dec_keys)
    s["decoder"] = stack_specs(_init_dec_layer(kd, cfg)[1])

    p["enc_norm"], s["enc_norm"] = _init_norm(cfg, dt)
    p["dec_norm"], s["dec_norm"] = _init_norm(cfg, dt)
    p["lm_head"] = L.dense_init(kp, (cfg.d_model, cfg.vocab_size), dt)
    s["lm_head"] = P("data", "model")
    return p, s


def encode(params, cfg: EncDecCfg, frame_embeds: jax.Array) -> jax.Array:
    """frame_embeds: (B, S_enc, D) from the stub frontend."""
    x = frame_embeds.astype(cfg.param_dtype)
    x = shard_hint(x, P(("pod", "data"), None, None))

    def body(carry, layer_params):
        fn = lambda c, lp: _apply_enc_layer(lp, cfg, c)
        if cfg.remat:
            fn = jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable)
        return fn(carry, layer_params), None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return _norm(cfg, params["enc_norm"], x)


def decode_train(params, cfg: EncDecCfg, tokens: jax.Array,
                 memory: jax.Array) -> jax.Array:
    """Teacher-forced decoder pass -> logits (B, S_dec, V)."""
    x = params["embed"][tokens]
    x = shard_hint(x, P(("pod", "data"), None, None))

    def body(carry, layer_params):
        def fn(c, lp):
            y, _ = _apply_dec_layer(lp, cfg, c, memory)
            return y
        if cfg.remat:
            fn = jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable)
        return fn(carry, layer_params), None

    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = _norm(cfg, params["dec_norm"], x)
    logits = x @ params["lm_head"]
    return shard_hint(logits, P(("pod", "data"), None, "model"))


def loss_fn(params, cfg: EncDecCfg, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict]:
    memory = encode(params, cfg, batch["frame_embeds"])
    logits = decode_train(params, cfg, batch["tokens"], memory)
    loss = T.cross_entropy(logits, batch["labels"])
    return loss, {"nll": loss, "loss": loss}


# ---------------------------------------------------------------------------
# Serving: prefill + decode with self-attn KV cache (+ stored memory)
# ---------------------------------------------------------------------------

def init_caches(cfg: EncDecCfg, batch: int, max_len: int, enc_len: int,
                dtype=jnp.bfloat16) -> Params:
    def one(_):
        return L.init_kv_cache(batch, max_len, cfg.attn, dtype)
    layer_caches = jax.vmap(one)(jnp.arange(cfg.dec_layers))
    return {"self": layer_caches,
            "memory": jnp.zeros((batch, enc_len, cfg.d_model), dtype)}


def cache_specs(cfg: EncDecCfg) -> Params:
    return {"self": stack_specs(L.kv_cache_specs(cfg.attn)),
            "memory": P(("pod", "data"), None, None)}


def _decoder_pass(params, cfg: EncDecCfg, x, memory, caches, *,
                  q_offset=0, decode: bool):
    def body(carry, xs):
        layer_params, layer_cache = xs
        y, nc = _apply_dec_layer(layer_params, cfg, carry, memory,
                                 q_offset=q_offset, cache=layer_cache,
                                 decode=decode)
        return y, nc

    x, new_caches = jax.lax.scan(body, x, (params["decoder"], caches))
    x = _norm(cfg, params["dec_norm"], x)
    return x @ params["lm_head"], new_caches


def prefill(params, cfg: EncDecCfg, batch: Dict[str, jax.Array],
            caches: Params) -> Tuple[jax.Array, Params]:
    memory = encode(params, cfg, batch["frame_embeds"])
    memory = memory.astype(caches["memory"].dtype)
    x = params["embed"][batch["tokens"]]
    logits, new_self = _decoder_pass(params, cfg, x, memory, caches["self"],
                                     q_offset=0, decode=False)
    return logits[:, -1], {"self": new_self, "memory": memory}


def decode_step(params, cfg: EncDecCfg, tokens: jax.Array, caches: Params
                ) -> Tuple[jax.Array, Params]:
    """tokens: (B, 1) -> (logits (B, V), caches)."""
    x = params["embed"][tokens]
    logits, new_self = _decoder_pass(
        params, cfg, x, caches["memory"].astype(cfg.param_dtype),
        caches["self"], decode=True)
    return logits[:, 0], {"self": new_self, "memory": caches["memory"]}
