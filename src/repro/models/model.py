"""build_model: uniform API over decoder-only and encoder-decoder stacks.

The Model object is what the substrate layers (train/serve/launch) consume:
  init / abstract_params / param_specs     — parameters
  loss                                      — training objective
  init_caches / cache_specs / prefill / decode_step — serving
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.models import encdec as ED
from repro.models import transformer as T

Params = Dict[str, Any]
Cfg = Union[T.TransformerCfg, ED.EncDecCfg]


@dataclasses.dataclass
class Model:
    cfg: Cfg

    @property
    def kind(self) -> str:
        return "encdec" if isinstance(self.cfg, ED.EncDecCfg) else "decoder"

    @property
    def name(self) -> str:
        return self.cfg.name

    # -- parameters -----------------------------------------------------

    def init(self, rng) -> Params:
        if self.kind == "encdec":
            return ED.init_params(rng, self.cfg)[0]
        return T.init_params(rng, self.cfg)[0]

    def abstract_params(self) -> Params:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def param_specs(self) -> Params:
        if self.kind == "encdec":
            return _specs_encdec(self.cfg)
        return _specs_decoder(self.cfg)

    def param_count(self) -> int:
        import math
        tree = self.abstract_params()
        return sum(math.prod(l.shape)
                   for l in jax.tree_util.tree_leaves(tree))

    # -- training ---------------------------------------------------------

    def loss(self, params: Params, batch: Dict[str, jax.Array]
             ) -> Tuple[jax.Array, Dict]:
        if self.kind == "encdec":
            return ED.loss_fn(params, self.cfg, batch)
        return T.loss_fn(params, self.cfg, batch)

    def logits(self, params: Params, batch: Dict[str, jax.Array]):
        if self.kind == "encdec":
            memory = ED.encode(params, self.cfg, batch["frame_embeds"])
            return ED.decode_train(params, self.cfg, batch["tokens"], memory)
        return T.logits_fn(params, self.cfg, batch)

    # -- serving ----------------------------------------------------------

    def init_caches(self, batch: int, max_len: int, *, enc_len: int = 0,
                    dtype=jnp.bfloat16) -> Params:
        if self.kind == "encdec":
            return ED.init_caches(self.cfg, batch, max_len, enc_len, dtype)
        return T.init_caches(self.cfg, batch, max_len, dtype)

    def cache_specs(self) -> Params:
        if self.kind == "encdec":
            return ED.cache_specs(self.cfg)
        return T.cache_specs(self.cfg)

    def prefill(self, params: Params, batch: Dict[str, jax.Array],
                caches: Params) -> Tuple[jax.Array, Params]:
        """Fill the cache from a prompt; returns (last-position logits,
        caches)."""
        if self.kind == "encdec":
            return ED.prefill(params, self.cfg, batch, caches)
        h, new_caches, _ = T.forward(params, self.cfg, batch, caches=caches,
                                     q_offset=0, decode=False)
        logits = T._unembed(params, self.cfg, h[:, -1:])
        return logits[:, 0], new_caches

    @property
    def supports_chunked_prefill(self) -> bool:
        """Whether every mixer has an absolute-position chunked prefill
        path (attn/mla).  Mamba's recurrent state is value-dependent, so
        a right-padded chunk would corrupt it — those models (and the
        enc-dec stack) prefill one-shot."""
        if self.kind == "encdec":
            return False
        return all(spec.mixer in ("attn", "mla")
                   for st in self.cfg.stages for spec in st.layers)

    def prefill_chunk(self, params: Params, batch: Dict[str, jax.Array],
                      caches: Params, *, q_offset, valid_len, last_index
                      ) -> Tuple[jax.Array, Params]:
        """One page-sized prefill chunk at TRACED ``q_offset`` (chunk
        index never forces a retrace).  The chunk is right-padded to the
        page boundary; ``valid_len`` clamps the cache length counters so
        pad positions don't count, and ``last_index`` (chunk-local, also
        traced) picks which position's logits to return — meaningful on
        the final chunk, where it is the prompt's last real token."""
        h, new_caches, _ = T.forward(
            params, self.cfg, batch, caches=caches, q_offset=q_offset,
            decode=False, chunked=True, valid_len=valid_len)
        h_last = jax.lax.dynamic_slice_in_dim(h, last_index, 1, axis=1)
        logits = T._unembed(params, self.cfg, h_last)
        return logits[:, 0], new_caches

    def decode_step(self, params: Params, batch: Dict[str, jax.Array],
                    caches: Params) -> Tuple[jax.Array, Params]:
        """One token for every sequence.  batch: {"tokens": (B, 1)} or
        {"inputs_embeds": (B, 1, D)}."""
        if self.kind == "encdec":
            return ED.decode_step(params, self.cfg, batch["tokens"], caches)
        h, new_caches, _ = T.forward(params, self.cfg, batch, caches=caches,
                                     decode=True)
        logits = T._unembed(params, self.cfg, h)
        return logits[:, 0], new_caches


def _specs_decoder(cfg: T.TransformerCfg) -> Params:
    return _eval_specs(lambda k: T.init_params(k, cfg))


def _specs_encdec(cfg: ED.EncDecCfg) -> Params:
    return _eval_specs(lambda k: ED.init_params(k, cfg))


def _eval_specs(init_fn: Callable) -> Params:
    """Spec trees are built by the init functions themselves; evaluate them
    without materializing parameters."""
    closure = {}

    def capture():
        _, specs = init_fn(jax.random.PRNGKey(0))
        closure["specs"] = specs
        return 0

    jax.eval_shape(capture)
    return closure["specs"]


def build_model(cfg: Cfg) -> Model:
    return Model(cfg=cfg)
