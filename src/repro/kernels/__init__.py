"""Pallas TPU kernels for the comm-stack and model compute hot spots.

Each kernel subpackage follows the pattern:
  kernel.py — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (auto interpret=True off-TPU)
  ref.py    — pure-jnp oracle used by tests and as the CPU fallback
"""

__all__ = ["flash_attention", "local_reduce", "quantize"]
