"""Public jit'd wrappers for the quantize kernel (flat-array API).

On a TPU backend the Pallas kernel runs compiled; elsewhere it runs in
interpret mode only when explicitly requested (tests), defaulting to the
jnp oracle which XLA-CPU fuses well anyway.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.quantize import kernel as K
from repro.kernels.quantize import ref

QBLOCK = K.QBLOCK


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _mode(force_kernel: bool | None) -> str:
    if force_kernel is None:
        return "kernel" if _on_tpu() else "ref"
    return "kernel" if force_kernel else "ref"


def quantize(x: jax.Array, block: int = QBLOCK,
             force_kernel: bool | None = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Flat x (n,), n % block == 0 -> (q int8 (n,), scales f32 (n/block,))."""
    assert block == QBLOCK, f"kernel is specialized for block={QBLOCK}"
    assert x.size % block == 0, (x.size, block)
    mode = _mode(force_kernel)
    if mode == "ref":
        return ref.quantize(x, block)
    rows = x.size // block
    pad_rows = (-rows) % K.ROWS_PER_TILE
    x2d = x.reshape(rows, block).astype(jnp.float32)
    if pad_rows:
        x2d = jnp.concatenate(
            [x2d, jnp.zeros((pad_rows, block), jnp.float32)])
    q2d, s2d = K.quantize_2d(x2d, interpret=not _on_tpu())
    return q2d[:rows].reshape(-1), s2d[:rows, 0]


def dequantize(q: jax.Array, scale: jax.Array, block: int = QBLOCK,
               dtype=jnp.float32, force_kernel: bool | None = None
               ) -> jax.Array:
    assert block == QBLOCK
    mode = _mode(force_kernel)
    if mode == "ref":
        return ref.dequantize(q, scale, block, dtype)
    rows = q.size // block
    pad_rows = (-rows) % K.ROWS_PER_TILE
    q2d = q.reshape(rows, block)
    s2d = scale.reshape(rows, 1)
    if pad_rows:
        q2d = jnp.concatenate([q2d, jnp.zeros((pad_rows, block), jnp.int8)])
        s2d = jnp.concatenate([s2d, jnp.ones((pad_rows, 1), jnp.float32)])
    x2d = K.dequantize_2d(q2d, s2d, dtype=dtype, interpret=not _on_tpu())
    return x2d[:rows].reshape(-1)


def dequant_add(acc: jax.Array, q: jax.Array, scale: jax.Array,
                block: int = QBLOCK, force_kernel: bool | None = None
                ) -> jax.Array:
    assert block == QBLOCK
    mode = _mode(force_kernel)
    if mode == "ref":
        return ref.dequant_add(acc, q, scale, block)
    rows = q.size // block
    pad_rows = (-rows) % K.ROWS_PER_TILE
    a2d = acc.reshape(rows, block)
    q2d = q.reshape(rows, block)
    s2d = scale.reshape(rows, 1)
    if pad_rows:
        a2d = jnp.concatenate([a2d, jnp.zeros((pad_rows, block), acc.dtype)])
        q2d = jnp.concatenate([q2d, jnp.zeros((pad_rows, block), jnp.int8)])
        s2d = jnp.concatenate([s2d, jnp.ones((pad_rows, 1), jnp.float32)])
    out = K.dequant_add_2d(a2d, q2d, s2d, interpret=not _on_tpu())
    return out[:rows].reshape(acc.shape)
