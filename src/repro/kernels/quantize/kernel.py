"""Pallas TPU kernel: blockwise symmetric int8 quantize / dequantize.

The compressed all-reduce protocol quantizes every ring hop; at 100+ MB
gradient chunks this is HBM-bandwidth-bound elementwise work, so the kernel
tiles it through VMEM.  Layout: the flat payload is viewed as
(n_qblocks, QBLOCK) with QBLOCK=256 (= 2x128 lanes); each grid step
processes ROWS_PER_TILE=8 quant-blocks, i.e. an (8, 256) VMEM tile — an
8x(2x128) native (sublane, lane) shape for f32.

One scale per row is emitted into an (n_qblocks, 1) f32 output.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 256        # quantization granularity (elements per scale)
ROWS_PER_TILE = 8   # quant blocks per grid step -> (8, 256) VMEM tiles


def _quantize_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)                    # (R, QBLOCK)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)     # (R, 1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale


def _dequantize_kernel(q_ref, scale_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = (q * scale_ref[...]).astype(x_ref.dtype)


def _dequant_add_kernel(acc_ref, q_ref, scale_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)
    out_ref[...] = (acc_ref[...].astype(jnp.float32)
                    + q * scale_ref[...]).astype(out_ref.dtype)


def _grid(rows: int) -> tuple:
    assert rows % ROWS_PER_TILE == 0, rows
    return (rows // ROWS_PER_TILE,)


def _row_spec():
    return pl.BlockSpec((ROWS_PER_TILE, QBLOCK), lambda i: (i, 0))


def _scale_spec():
    return pl.BlockSpec((ROWS_PER_TILE, 1), lambda i: (i, 0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_2d(x2d: jax.Array, *, interpret: bool = False):
    """x2d: (rows, QBLOCK) float -> (int8 (rows, QBLOCK), f32 (rows, 1))."""
    rows = x2d.shape[0]
    return pl.pallas_call(
        _quantize_kernel,
        grid=_grid(rows),
        in_specs=[_row_spec()],
        out_specs=(_row_spec(), _scale_spec()),
        out_shape=(
            jax.ShapeDtypeStruct((rows, QBLOCK), jnp.int8),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ),
        interpret=interpret,
    )(x2d)


@functools.partial(jax.jit, static_argnames=("dtype", "interpret"))
def dequantize_2d(q2d: jax.Array, scale: jax.Array, *,
                  dtype=jnp.float32, interpret: bool = False):
    rows = q2d.shape[0]
    return pl.pallas_call(
        _dequantize_kernel,
        grid=_grid(rows),
        in_specs=[_row_spec(), _scale_spec()],
        out_specs=_row_spec(),
        out_shape=jax.ShapeDtypeStruct((rows, QBLOCK), dtype),
        interpret=interpret,
    )(q2d, scale)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequant_add_2d(acc2d: jax.Array, q2d: jax.Array, scale: jax.Array, *,
                   interpret: bool = False):
    """Fused receive path of the compressed ring: acc + q * scale."""
    rows = q2d.shape[0]
    return pl.pallas_call(
        _dequant_add_kernel,
        grid=_grid(rows),
        in_specs=[_row_spec(), _row_spec(), _scale_spec()],
        out_specs=_row_spec(),
        out_shape=jax.ShapeDtypeStruct((rows, QBLOCK), acc2d.dtype),
        interpret=interpret,
    )(acc2d, q2d, scale)
