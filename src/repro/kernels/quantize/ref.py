"""Pure-jnp oracle for blockwise int8 quantization (compression protocol)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize(x: jax.Array, block: int = 256) -> Tuple[jax.Array, jax.Array]:
    """x: (n,) with n % block == 0 -> (q int8 (n,), scales f32 (n/block,))."""
    xb = x.reshape(-1, block).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0]


def dequantize(q: jax.Array, scale: jax.Array, block: int = 256,
               dtype=jnp.float32) -> jax.Array:
    qb = q.reshape(-1, block).astype(jnp.float32)
    return (qb * scale[:, None]).astype(dtype).reshape(-1)


def dequant_add(acc: jax.Array, q: jax.Array, scale: jax.Array,
                block: int = 256) -> jax.Array:
    """Fused receive-side op of the compressed ring: acc + dequant(q)."""
    return acc + dequantize(q, scale, block, acc.dtype)
