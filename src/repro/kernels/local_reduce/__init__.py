from repro.kernels.local_reduce import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
