"""Public jit'd wrapper for the tiled chunk reduction (flat API)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.local_reduce import kernel as K
from repro.kernels.local_reduce import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sum_chunks(x: jax.Array, dtype=None,
               force_kernel: bool | None = None) -> jax.Array:
    """x: (k, n) -> (n,) sum accumulated in f32."""
    dtype = dtype or x.dtype
    use_kernel = force_kernel if force_kernel is not None else _on_tpu()
    if not use_kernel:
        return ref.sum_chunks(x, dtype)
    k, n = x.shape
    tile = K.TILE_ROWS * K.LANES
    pad = (-n) % tile
    xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    x3 = xp.reshape(k, -1, K.LANES)
    out = K.sum_chunks_3d(x3, interpret=not _on_tpu())
    return out.reshape(-1)[:n].astype(dtype)
