"""Pure-jnp oracle for the tiled chunk reduction."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sum_chunks(x: jax.Array, dtype=jnp.float32) -> jax.Array:
    """x: (k, n) stacked contributions -> (n,) sum, accumulated in f32."""
    return jnp.sum(x.astype(jnp.float32), axis=0).astype(dtype)
