"""Pallas TPU kernel: tiled k-way chunk reduction (ring/two-phase inner op).

Reduce-scatter phases materialize k received contributions that must be
summed into one chunk.  Summing k large HBM-resident chunks is pure
memory-bandwidth work; the kernel streams (TILE_ROWS, 128) VMEM tiles and
accumulates across the k grid dimension in the revisited output block, so
each output byte is written once (vs k-1 times for a naive jnp.sum chain
of adds when XLA fails to fuse across collective boundaries).

Grid: (row_tiles, k) with k innermost ("arbitrary") so the output block
stays resident in VMEM across the whole accumulation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
TILE_ROWS = 8  # (8, 128) f32 native tile


def _sum_kernel(x_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sum_chunks_3d(x: jax.Array, *, interpret: bool = False) -> jax.Array:
    """x: (k, rows, LANES) -> (rows, LANES) f32 sum."""
    k, rows, lanes = x.shape
    assert lanes == LANES and rows % TILE_ROWS == 0, x.shape
    return pl.pallas_call(
        _sum_kernel,
        grid=(rows // TILE_ROWS, k),
        in_specs=[pl.BlockSpec((1, TILE_ROWS, LANES), lambda i, j: (j, i, 0))],
        out_specs=pl.BlockSpec((TILE_ROWS, LANES), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(x)
