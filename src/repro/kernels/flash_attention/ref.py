"""Pure-jnp oracle: exact softmax attention with optional causal mask.

Shapes follow the kernel's flattened convention:
  q: (BH, Sq, D)   k, v: (BHkv, Skv, D)   with BH % BHkv == 0 (GQA groups).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, sm_scale: float | None = None,
              q_offset: int = 0) -> jax.Array:
    """Exact attention.  ``q_offset`` places the query block at absolute
    position ``q_offset + i`` for causal masking (decode: q_offset = cache
    length so the single new token sees the whole prefix)."""
    bh, sq, d = q.shape
    bhkv = k.shape[0]
    group = bh // bhkv
    sm_scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    kx = jnp.repeat(k, group, axis=0)
    vx = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * sm_scale
    if causal:
        qi = jnp.arange(sq)[:, None] + q_offset
        ki = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(qi >= ki, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)
