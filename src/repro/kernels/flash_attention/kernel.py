"""Pallas TPU kernel: blockwise (flash) attention forward.

TPU-native adaptation: the GPU flash algorithm tiles for shared memory per
SM; here the tiling targets VMEM and the MXU.  Q/K/V blocks are
(BLOCK_Q, D) / (BLOCK_K, D) with D the full head dim (MXU-aligned, 128|256),
the running max/denominator live in VMEM scratch that persists across the
innermost (kv) grid dimension, and the S = Q·Kᵀ / O += P·V contractions are
MXU matmuls with f32 accumulation (``preferred_element_type``).

Grid: (BH, num_q_blocks, num_kv_blocks); kv innermost ("arbitrary"), so the
(m, l, acc) scratch carries across kv steps.  Causal blocks strictly above
the diagonal are skipped with ``pl.when`` — ~2x fewer MXU flops at train
shapes.  GQA is expressed in the K/V index maps (query head h reads kv head
h // group), so no repeated KV is ever materialized in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across pallas versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  sm_scale: float, causal: bool, block_q: int, block_k: int,
                  num_kv_blocks: int, q_offset: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Causal: the diagonal block of queries starts at q_offset + qi*block_q;
    # kv blocks strictly past the last query position contribute nothing.
    run = True
    if causal:
        last_q = q_offset + (qi + 1) * block_q - 1
        run = kj * block_k <= last_q

    @pl.when(run)
    def _body():
        q = q_ref[0]                       # (block_q, D)
        k = k_ref[0]                       # (block_k, D)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (bq, bk)
        if causal:
            qpos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = kj * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            mask = qpos >= kpos
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, :1]                                # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        if causal:
            p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                      # (bq, 1)
        l_ref[:, :1] = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        m_ref[:, :1] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(kj == num_kv_blocks - 1)
    def _finish():
        l = l_ref[:, :1]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sm_scale", "block_q", "block_k", "q_offset",
                     "interpret"))
def flash_attention_bhsd(
    q: jax.Array,      # (BH, Sq, D)
    k: jax.Array,      # (BHkv, Skv, D)
    v: jax.Array,      # (BHkv, Skv, D)
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    q_offset: int = 0,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, d = q.shape
    bhkv, skv, _ = k.shape
    assert bh % bhkv == 0, (bh, bhkv)
    group = bh // bhkv
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q, block_k)
    sm_scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    num_q = sq // block_q
    num_kv = skv // block_k

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, block_q=block_q,
        block_k=block_k, num_kv_blocks=num_kv, q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid=(bh, num_q, num_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # running max
            pltpu.VMEM((block_q, 128), jnp.float32),   # running denom
            pltpu.VMEM((block_q, d), jnp.float32),     # accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
