"""Public attention op: (B, S, H, D) layout, GQA-aware, kernel/oracle switch.

``attention`` is what the model layers call.  It routes to the Pallas
kernel on TPU (or in interpret mode when forced by tests) and to the exact
jnp oracle elsewhere.  The custom-VJP backward recomputes attention with
the oracle (flash backward is a follow-up kernel; recompute-backward is
the standard remat policy at these sizes anyway).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _flatten(x):  # (B, S, H, D) -> (B*H, S, D)
    b, s, h, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)


def _unflatten(x, b):  # (B*H, S, D) -> (B, S, H, D)
    bh, s, d = x.shape
    return x.reshape(b, bh // b, s, d).transpose(0, 2, 1, 3)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, sm_scale: float | None = None,
              q_offset: int = 0, force_kernel: bool | None = None,
              block_q: int = K.DEFAULT_BLOCK_Q,
              block_k: int = K.DEFAULT_BLOCK_K) -> jax.Array:
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D); returns (B, Sq, Hq, D)."""
    b = q.shape[0]
    qf, kf, vf = _flatten(q), _flatten(k), _flatten(v)
    use_kernel = force_kernel if force_kernel is not None else _on_tpu()
    if use_kernel:
        out = K.flash_attention_bhsd(
            qf, kf, vf, causal=causal, sm_scale=sm_scale,
            block_q=block_q, block_k=block_k, q_offset=q_offset,
            interpret=not _on_tpu())
    else:
        out = ref.attention(qf, kf, vf, causal=causal, sm_scale=sm_scale,
                            q_offset=q_offset)
    return _unflatten(out, b)
