"""Optimizers: AdamW (bf16-state option) and Adafactor (factored second
moment) — the latter is what makes 340B–671B fit the optimizer-state
budget on a 256-chip pod (distributed-memory trick: factored V costs
O(rows+cols) instead of O(rows·cols)).

Pure-functional API:  state = opt.init(params); params, state =
opt.update(grads, state, params).  Update math runs in f32 regardless of
param/state dtype; global-norm clipping and cosine LR live here too.
State sharding specs mirror the param specs (factored vectors drop the
corresponding axis).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Any


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(tree, max_norm: float, norm=None):
    n = global_norm(tree) if norm is None else norm
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), n


@dataclasses.dataclass(frozen=True)
class AdamWCfg:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32      # bf16 halves optimizer memory


@dataclasses.dataclass(frozen=True)
class AdafactorCfg:
    lr: Callable | float = 1e-2
    decay: float = 0.8                  # \hat{beta2}(t) = 1 - t^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0         # update RMS clip (per-tensor)
    weight_decay: float = 0.0
    clip_norm: float = 0.0              # 0 = rely on update clipping
    min_dim_factored: int = 128         # don't factor tiny tensors


@dataclasses.dataclass
class Optimizer:
    init: Callable[[Params], Any]
    update: Callable[..., Tuple[Params, Any]]
    state_specs: Callable[[Params], Any]
    name: str = "adamw"


def _lr_at(lr, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def _map_leading(fn, *trees, threshold: int = 4):
    """Apply a per-leaf update slice-by-slice over the leading (stacked
    layers) dim when it is large.  The update math runs in f32; on a
    stacked MoE leaf like (58, 256, 7168, 2048) materializing f32 temps of
    the full leaf costs several x 3.4 GB/device — lax.map keeps the
    working set to one layer's slice."""
    lead = trees[0].shape[0] if trees[0].ndim >= 1 else 0
    if trees[0].ndim >= 3 and lead > threshold:
        return jax.lax.map(lambda xs: fn(*xs), trees)
    return fn(*trees)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def make_adamw(cfg: AdamWCfg) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, global_norm_fn=None):
        step = state["step"] + 1
        gnorm = (global_norm_fn or global_norm)(grads)
        if cfg.clip_norm:
            grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm,
                                               norm=gnorm)
        t = step.astype(jnp.float32)
        bc1 = 1 - cfg.b1 ** t
        bc2 = 1 - cfg.b2 ** t
        lr = _lr_at(cfg.lr, step)

        def leaf_core(p, g, m, v):
            gf = g.astype(jnp.float32)
            mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
            vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
            upd = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
            pf = p.astype(jnp.float32)
            pf = pf - lr * (upd + cfg.weight_decay * pf)
            return (pf.astype(p.dtype), mf.astype(cfg.state_dtype),
                    vf.astype(cfg.state_dtype))

        def leaf(p, g, m, v):
            return _map_leading(leaf_core, p, g, m, v)

        out = jax.tree_util.tree_map(leaf, params, grads,
                                     state["m"], state["v"])
        new_params = jax.tree_util.tree_map(lambda o: o[0], out,
                                            is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda o: o[2], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v, "step": step}, \
            {"grad_norm": gnorm, "lr": lr}

    def state_specs(param_specs, abstract_params=None):
        return {"m": param_specs, "v": param_specs, "step": P()}

    return Optimizer(init=init, update=update, state_specs=state_specs,
                     name="adamw")


# ---------------------------------------------------------------------------
# Adafactor
# ---------------------------------------------------------------------------

def _factored(shape, min_dim: int = 128) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_dim and shape[-2] >= min_dim


def make_adafactor(cfg: AdafactorCfg) -> Optimizer:
    def init(params):
        def leaf(p):
            if _factored(p.shape, cfg.min_dim_factored):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree_util.tree_map(leaf, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, global_norm_fn=None):
        step = state["step"] + 1
        gnorm = (global_norm_fn or global_norm)(grads)
        if cfg.clip_norm:
            grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm,
                                               norm=gnorm)
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-cfg.decay)
        lr = _lr_at(cfg.lr, step)

        def leaf(p, g, s):
            return _map_leading(lambda ps, gs, ss: leaf_core(ps, gs, ss),
                                p, g, s)

        def leaf_core(p, g, s):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + cfg.eps
            if "vr" in s:
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                # upd = g / (sqrt(vr_hat) ⊗ sqrt(vc)); vr_hat = vr/mean(vr).
                upd = gf * jax.lax.rsqrt(
                    jnp.maximum(vr[..., None], cfg.eps)) \
                    * jax.lax.rsqrt(jnp.maximum(vc[..., None, :], cfg.eps)) \
                    * jnp.sqrt(jnp.maximum(jnp.mean(vr, -1), cfg.eps)
                               )[..., None, None]
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                upd = gf * jax.lax.rsqrt(jnp.maximum(v, cfg.eps))
                new_s = {"v": v}
            # RMS clip (Adafactor's update clipping).
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-30)
            upd = upd / jnp.maximum(1.0, rms / cfg.clip_threshold)
            pf = p.astype(jnp.float32)
            pf = pf - lr * (upd + cfg.weight_decay * pf)
            return pf.astype(p.dtype), new_s

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["f"])
        outs = [leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = tdef.unflatten([o[0] for o in outs])
        new_f = tdef.unflatten([o[1] for o in outs])
        return new_params, {"f": new_f, "step": step}, \
            {"grad_norm": gnorm, "lr": lr}

    def state_specs(param_specs, abstract_params=None):
        def leaf(spec, p=None):
            # vr drops the last axis of the spec, vc the second-to-last —
            # but only for leaves the init actually factors (shape-based).
            entries = tuple(spec)
            factored = (_factored(p.shape, cfg.min_dim_factored)
                        if p is not None else len(entries) >= 2)
            if factored:
                while len(entries) < (len(p.shape) if p is not None else 2):
                    entries = entries + (None,)
                return {"vr": P(*entries[:-1]),
                        "vc": P(*(entries[:-2] + entries[-1:]))}
            return {"v": spec}
        if abstract_params is not None:
            f = jax.tree_util.tree_map(
                lambda s, p: leaf(s, p), param_specs, abstract_params,
                is_leaf=lambda s: isinstance(s, P))
        else:
            f = jax.tree_util.tree_map(leaf, param_specs,
                                       is_leaf=lambda s: isinstance(s, P))
        return {"f": f, "step": P()}

    return Optimizer(init=init, update=update, state_specs=state_specs,
                     name="adafactor")


def make_optimizer(name: str, **kwargs) -> Optimizer:
    if name == "adamw":
        return make_adamw(AdamWCfg(**kwargs))
    if name == "adafactor":
        return make_adafactor(AdafactorCfg(**kwargs))
    raise ValueError(f"unknown optimizer {name!r}")
