from repro.optim.optimizer import (AdafactorCfg, AdamWCfg, Optimizer,
                                   cosine_schedule, make_optimizer)

__all__ = ["AdafactorCfg", "AdamWCfg", "Optimizer", "cosine_schedule",
           "make_optimizer"]
