"""Quickstart: open a Sessions-style communication session for your
application and train a small model with it (paper §2 flow, end to end,
through the ``repro.comm`` facade — the only public way to do distributed
work in this repo).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro import comm as comm_mod
from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.optim import make_optimizer
from repro.train import TrainCfg, make_train_state, make_train_step


def main():
    # 1. the application: a reduced Qwen3-MoE training step
    cfg = get_config("qwen3-moe-30b-a3b", reduced=True)
    model = build_model(cfg)
    opt = make_optimizer("adamw", lr=3e-3)
    tcfg = TrainCfg(microbatches=2)
    step = make_train_step(model, opt, tcfg)

    # 2. scan it (paper §2.2: "the application code is scanned to record
    #    invoked MPI functions") and compose the thin library — one call:
    #    a probe session supplies the abstract (4, 2) mesh the composed
    #    step is traced over (nothing executes, nothing is allocated) and
    #    records the engine-level functions the step invokes.
    mesh = make_host_mesh()
    probe = comm_mod.Session.probe((4, 2), ("data", "model"))
    probe_cfg = TrainCfg(microbatches=2, sync_mode="composed",
                         data_axes=("data",))
    probe_step = make_train_step(model, opt, probe_cfg, mesh=probe.mesh,
                                 comm=probe.world)
    state_abs = make_train_state(model, opt, abstract=True, cfg=probe_cfg)
    batch_abs = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
    session = comm_mod.Session.from_application(
        probe_step, state_abs, batch_abs, mesh=mesh, probe=probe)
    print("— traced collective profile —")
    print(session.trace_report.summary())
    print("\n— composed session —")
    print(session.describe())

    # 3. communicators + persistent handles: the world communicator spans
    #    every mesh axis; split() gives per-axis sub-communicators; a
    #    persistent handle pre-binds protocol + tier + mean scale once
    #    (MPI_Allreduce_init-style), so calls are zero-lookup.
    dcomm = session.split("data")
    handle = dcomm.persistent("all_reduce", (64,), jnp.float32, mean=True)
    print("\npersistent handle:", handle.describe())
    print(f"avg layer number with handles: "
          f"{session.average_layer_number():.4f}")

    # 4. train with it
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=64,
                            global_batch=8)
    with session.activate():
        state = make_train_state(model, opt, jax.random.PRNGKey(0), cfg=tcfg)
        jstep = jax.jit(step, donate_argnums=0)
        for i in range(20):
            batch = ds.sharded_batch(i, mesh)
            state, metrics = jstep(state, batch)
            if i % 5 == 0 or i == 19:
                print(f"step {i:3d}  loss {float(metrics['loss']):.4f}")
    print("\nsession stats:\n" + session.finalize())


if __name__ == "__main__":
    main()
