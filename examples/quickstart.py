"""Quickstart: compose a thin collective engine for your application and
train a small model with it (paper §2 flow, end to end).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import CollectiveEngine, scan_step
from repro.core.compose import compose_from_trace
from repro.core.topology import topology_from_mesh
from repro.data import SyntheticLMDataset
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.optim import make_optimizer
from repro.train import TrainCfg, make_train_state, make_train_step


def main():
    # 1. the application: a reduced Qwen3-MoE training step
    cfg = get_config("qwen3-moe-30b-a3b", reduced=True)
    model = build_model(cfg)
    opt = make_optimizer("adamw", lr=3e-3)
    tcfg = TrainCfg(microbatches=2)
    step = make_train_step(model, opt, tcfg)

    # 2. scan it (paper §2.2: "the application code is scanned to record
    #    invoked MPI functions") — traced on an abstract (4, 2) mesh so
    #    the composed collectives appear as jaxpr primitives; nothing is
    #    executed or allocated.
    from repro.core import EngineConfig, compose_library, registry
    from repro.core.topology import topology_from_mesh_shape
    from repro.runtime import substrate
    from repro.train import trainer
    mesh = make_host_mesh()
    amesh = substrate.abstract_mesh((4, 2), ("data", "model"))
    probe_cfg = trainer.TrainCfg(microbatches=2, sync_mode="composed",
                                 data_axes=("data",))
    probe_eng = CollectiveEngine(
        topology_from_mesh_shape(("data", "model"), (4, 2)),
        library=compose_library(registry.ALL_FUNCTIONS),
        config=EngineConfig(mode="composed"))
    probe = make_train_step(model, opt, probe_cfg, mesh=amesh,
                            engine=probe_eng)
    state = make_train_state(model, opt, abstract=True, cfg=probe_cfg)
    batch_abs = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
    with substrate.use_abstract_mesh(amesh):
        report = scan_step(probe, state, batch_abs)
    print("— traced collective profile —")
    print(report.summary())

    # 3. compose the thin library and build the engine (the probe engine
    #    recorded which engine-level functions the step invoked; the
    #    jaxpr scan alone sees only their protocol lowering)
    library = compose_from_trace(report, extra=probe_eng.invoked_functions)
    engine = CollectiveEngine(
        topology_from_mesh(mesh), library=library,
        frequencies={fn: c * 1e4 for fn, c in report.frequencies().items()})
    print("\n— composed engine —")
    print(engine.describe())

    # 4. train with it
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=64,
                            global_batch=8)
    with substrate.set_mesh(mesh):
        state = make_train_state(model, opt, jax.random.PRNGKey(0), cfg=tcfg)
        jstep = jax.jit(step, donate_argnums=0)
        for i in range(20):
            batch = ds.sharded_batch(i, mesh)
            state, metrics = jstep(state, batch)
            if i % 5 == 0 or i == 19:
                print(f"step {i:3d}  loss {float(metrics['loss']):.4f}")
    print("\nengine stats:\n" + engine.finalize())


if __name__ == "__main__":
    main()
