"""Beyond-paper: pipeline parallelism over the (slow) cross-pod axis.

The engine's p2p protocol (`core/protocols/pipeline.py`) schedules a
GPipe-style microbatch pipeline with one `ppermute` hop per tick — on the
production mesh the "pod" axis would carry only stage boundaries
((B_micro, S, D) per tick) over DCN instead of data-parallel gradient
all-reduces (2x params per step), trading DCN bandwidth for bubble time.

This example runs the pipeline on emulated devices and prints the
bubble/traffic arithmetic for the production mesh.

    PYTHONPATH=src python examples/pipeline_parallel.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P

from repro.comm import collectives as cc
from repro.core.protocols import pipeline
from repro.runtime import substrate


def main():
    p = 4                    # pipeline stages (one per device here)
    n_micro = 8
    d = 64

    mesh = substrate.make_mesh((p,), ("stage",))
    rng = np.random.RandomState(0)
    stage_w = jnp.asarray(rng.randn(p, d, d).astype(np.float32) * 0.1)
    micro = jnp.asarray(rng.randn(n_micro, 16, d).astype(np.float32))

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    @partial(substrate.shard_map, mesh=mesh, in_specs=(P("stage"), P()),
             out_specs=P(), check_vma=False)
    def run(w, mb):
        out = pipeline.gpipe_forward(stage_fn, w[0], mb, "stage")
        # only the last stage's buffer is meaningful; broadcast it
        last = cc.psum(
            jnp.where(cc.axis_index("stage") == p - 1, out, 0.0),
            "stage")
        return last

    out = jax.jit(run)(stage_w, micro)

    # reference: sequential through all stages
    ref = micro
    for s in range(p):
        ref = stage_fn(stage_w[s], ref)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    print(f"pipeline({p} stages, {n_micro} microbatches) == sequential  OK")

    bubble = (p - 1) / (n_micro + p - 1)
    print(f"bubble fraction: {bubble:.1%}")

    # production-mesh arithmetic: 2 pods as 2 pipeline stages over DCN
    params_b = 340e9 * 2                   # nemotron-class, bf16
    act_b = 2 * 4096 * 18432 * 2           # one microbatch boundary
    dp_bytes = 2 * params_b / 2            # grad all-reduce over 2 pods
    pp_bytes = 2 * 8 * act_b               # fwd+bwd boundaries, 8 micro
    print(f"cross-pod DCN traffic/step: data-parallel {dp_bytes/1e9:.0f} GB "
          f"vs pipeline {pp_bytes/1e9:.2f} GB "
          f"({dp_bytes/pp_bytes:,.0f}x less) at {bubble:.0%} bubble cost")


if __name__ == "__main__":
    main()
