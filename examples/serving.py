"""Serving example: elastic continuous batching over a reduced GQA model
(driven by the ``ServeController``, which owns the drain -> re-mesh ->
re-admit failure lifecycle), plus a deepseek-style MLA model to show the
compressed-cache decode path.  The serving mesh is owned by a
``repro.comm.Session`` (the facade); the controller and ``generate`` run
under it.

    PYTHONPATH=src python examples/serving.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import comm as comm_mod
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serve import Request, ServeCfg, ServeController, generate


def main():
    rng = np.random.RandomState(0)

    # The session is the one entity owning device/mesh concerns; hand its
    # world communicator to the serving engine.
    session = comm_mod.Session(mesh=make_host_mesh(model_parallel=1))
    comm = session.world
    print("serving session:", comm.describe())

    # --- elastic continuous batching on a GQA decoder ------------------
    # The controller supervises the slot scheduler: on device loss (or a
    # rehearse_recovery fire drill, below) it drains in-flight decode,
    # snapshots per-slot KV caches, re-meshes the session over the
    # survivors, and re-admits — every in-flight request's remaining
    # tokens bit-identical (sampling is pure in (seed, rid, position)).
    cfg = get_config("qwen2-72b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ctl = ServeController(model, params,
                          ServeCfg(max_len=96, batch=4,
                                   cache_dtype=jnp.float32),
                          comm=comm)
    t0 = time.time()
    for rid in range(10):
        prompt = rng.randint(0, cfg.vocab_size,
                             size=rng.randint(4, 20)).tolist()
        ctl.submit(Request(rid=rid, prompt=prompt, max_new=16))
    report = ctl.run()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in report.completed)
    print(f"[elastic continuous batching] {len(report.completed)} "
          f"requests, {toks} tokens in {dt:.2f}s ({toks / dt:.1f} tok/s, "
          f"4 slots, meshes={report.mesh_history})")

    # fire drill: full drain -> snapshot -> re-mesh -> re-admit, nothing
    # lost — the honest recovery-latency number without killing a device
    for rid in range(10, 13):
        ctl.submit(Request(rid=rid,
                           prompt=rng.randint(0, cfg.vocab_size,
                                              size=8).tolist(),
                           max_new=8))
    for _ in range(2):
        ctl.sched.step()
    rec = ctl.rehearse_recovery()
    ctl.run()
    print(f"[recovery rehearsal] drain+snapshot {rec.snapshot_s * 1e3:.0f}"
          f"ms, remesh {rec.remesh_s * 1e3:.0f}ms, rebuild "
          f"{rec.rebuild_s * 1e3:.0f}ms -> {rec.total_s * 1e3:.0f}ms; "
          f"resumed={rec.resumed} in-flight bit-identically")

    # --- paged KV cache + chunked prefill (PR 9) ------------------------
    # One LONG prompt next to short ones: one-shot prefill would stall
    # every slot while the long prompt runs; chunked prefill feeds it to
    # the pool one page (16 tokens here) per step, interleaved with the
    # short requests' decode.  The pool backs each request with only the
    # pages its tokens occupy — resident bytes track generated length,
    # not batch * max_len — and the streams are bit-identical to the
    # one-shot path (chunking never shows up in the tokens).
    from repro.serve import BatchScheduler

    scfg = ServeCfg(max_len=96, batch=4, cache_dtype=jnp.float32,
                    page_tokens=16, chunked_prefill=True)
    sched = BatchScheduler(model, params, scfg, comm=comm)
    long_prompt = rng.randint(0, cfg.vocab_size, size=70).tolist()
    sched.submit(Request(rid=0, prompt=long_prompt, max_new=8))
    for rid in range(1, 4):
        sched.submit(Request(rid=rid,
                             prompt=rng.randint(0, cfg.vocab_size,
                                                size=5).tolist(),
                             max_new=8))
    pool = sched.pool
    steps_while_prefilling = 0
    peak = pool.resident_bytes()
    while sched.pending():
        sched.step()
        peak = max(peak, pool.resident_bytes())
        if 0 in sched._prefills:
            steps_while_prefilling += 1
    print(f"[paged + chunked prefill] 70-token prompt prefilled in "
          f"{-(-70 // scfg.page_tokens)} page chunks while short requests "
          f"decoded ({steps_while_prefilling} interleaved steps); pool "
          f"{pool.pages_total} pages x {pool.page_tokens} tokens, peak "
          f"resident {peak:,d} B vs contiguous "
          f"{pool.contiguous_bytes():,d} B")

    # --- MLA absorbed-decode (compressed KV cache) ----------------------
    cfg = get_config("deepseek-v3-671b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompts = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 12)), jnp.int32)
    out = generate(model, params, prompts, max_new=8,
                   cfg=ServeCfg(max_len=64, batch=2,
                                cache_dtype=jnp.float32),
                   comm=comm)
    # cache footprint comparison: latent (kv_lora + dh_rope) vs dense H*Dh
    mla = cfg.mla
    latent = mla.kv_lora + mla.dh_rope
    dense = 2 * mla.num_heads * mla.dh_v
    print(f"[MLA decode] generated {out.shape[1] - prompts.shape[1]} tokens"
          f"/seq; cache = {latent} floats/token/layer vs {dense} for dense "
          f"MHA ({dense / latent:.0f}x smaller)")


if __name__ == "__main__":
    main()
