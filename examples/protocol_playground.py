"""Protocol playground: inspect the paper's per-function protocol
selection on your mesh topology, and force alternatives.

    PYTHONPATH=src python examples/protocol_playground.py
"""

import numpy as np

from repro.core import costmodel, topology_from_mesh_shape
from repro.core.costmodel import crossover_bytes


def main():
    topo = topology_from_mesh_shape(("pod", "data", "model"), (2, 16, 16))
    print("topology:", topo.describe(), "\n")

    print("protocol crossovers for all_reduce over the ICI 'data' axis:")
    for proto, (lo, hi) in sorted(
            crossover_bytes("all_reduce", topo, "data").items(),
            key=lambda kv: kv[1][0]):
        print(f"  {proto:<22s} wins [{lo:>14,.0f} B .. {hi:>14,.0f} B]")

    print("\nper-size winners across functions (data axis, p=16):")
    header = f"{'bytes':>12s} | " + " | ".join(
        f"{c:^18s}" for c in ("all_reduce", "all_gather", "all_to_all"))
    print(header)
    print("-" * len(header))
    for nbytes in (1 << 10, 1 << 16, 1 << 22, 1 << 28):
        row = [f"{nbytes:>12,d}"]
        for coll in ("all_reduce", "all_gather", "all_to_all"):
            c = costmodel.choose_protocol(coll, nbytes, topo, "data")
            row.append(f"{c.protocol:^18s}")
        print(" | ".join(row))

    print("\nsame message on the DCN 'pod' axis (p=2, 10us alpha):")
    for nbytes in (1 << 10, 1 << 22, 1 << 30):
        c = costmodel.choose_protocol("all_reduce", nbytes, topo, "pod")
        print(f"  {nbytes:>14,d} B -> {c.protocol:<20s} "
              f"(~{c.est_seconds * 1e6:,.1f} us)")

    print("\nhierarchical cross-pod all-reduce vs flat ring (1 GiB):")
    n = 1 << 30
    flat = costmodel.cost_allreduce_ring(n, topo, "pod")
    hier = costmodel.cost_allreduce_hierarchical(n, topo,
                                                 ("data", "model"), "pod")
    print(f"  flat DCN ring:   {flat * 1e3:8.2f} ms")
    print(f"  hierarchical:    {hier * 1e3:8.2f} ms  "
          f"({flat / hier:.1f}x faster, DCN bytes /256)")


if __name__ == "__main__":
    main()
