"""End-to-end driver: train a ~100M-param dense LM for a few hundred
steps with checkpointing, watchdog, and crash recovery.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]

This is the (b) deliverable's "train ~100M model" example: a granite-style
stack scaled to ~100M params, synthetic data, cosine schedule, async
checkpoints every 50 steps; interrupt it and re-run — it resumes.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLMDataset
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.models.layers import AttentionCfg, MLPCfg
from repro.models.transformer import (LayerSpec, StageSpec, TransformerCfg)
from repro.optim import cosine_schedule, make_optimizer
from repro.parallel.sharding import named_shardings
from repro.runtime import StepWatchdog, substrate
from repro.train import TrainCfg, make_train_state, make_train_step, trainer


def model_100m():
    d = 512
    return TransformerCfg(
        name="demo-100m", d_model=d, vocab_size=32_000,
        stages=(StageSpec((LayerSpec("attn", "dense"),), repeat=8),),
        attn=AttentionCfg(d_model=d, num_heads=8, num_kv_heads=4,
                          head_dim=64),
        mlp=MLPCfg(d, 2048, "swiglu"),
        block_k=128,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_e2e")
    args = ap.parse_args()

    cfg = model_100m()
    model = build_model(cfg)
    print(f"model: {model.param_count() / 1e6:.1f}M params")
    opt = make_optimizer(
        "adamw", lr=cosine_schedule(3e-4, warmup=20, total=args.steps))
    tcfg = TrainCfg(microbatches=2)
    mesh = make_host_mesh()
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                            global_batch=args.global_batch)
    step = make_train_step(model, opt, tcfg)
    sspecs = trainer.state_specs(model, opt, tcfg)

    with substrate.set_mesh(mesh):
        state = make_train_state(model, opt, jax.random.PRNGKey(0), cfg=tcfg)
        state = jax.device_put(state, named_shardings(mesh, sspecs))
        jstep = jax.jit(step, donate_argnums=0)
        ckpt = CheckpointManager(args.ckpt_dir, every=50, keep=2)
        restored, rstep = ckpt.restore_latest(
            jax.eval_shape(lambda: state), named_shardings(mesh, sspecs))
        start = 0
        if restored is not None:
            state, start = restored, rstep
            print(f"resumed from checkpoint at step {start}")
        wd = StepWatchdog(timeout=120).start()
        t0 = time.time()
        for i in range(start, args.steps):
            state, metrics = jstep(state, ds.sharded_batch(i, mesh))
            wd.beat()
            ckpt.maybe_save(i + 1, state)
            if i % 25 == 0 or i == args.steps - 1:
                tok_s = (i - start + 1) * ds.global_batch * ds.seq_len \
                    / (time.time() - t0)
                print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                      f"lr {float(metrics['lr']):.2e}  {tok_s:,.0f} tok/s")
        wd.stop()
        ckpt.maybe_save(args.steps, state, force=True)
        ckpt.wait()
        print(f"done; stragglers detected: {len(wd.stragglers)}")


if __name__ == "__main__":
    main()
